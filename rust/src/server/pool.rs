//! The persistent worker pool: one set of long-lived workers serving
//! tasks from *all* currently-active jobs, decoupled from any single
//! `Scheduler::run` call.
//!
//! Where the paper's executor (`coordinator/exec.rs`) spawns workers for
//! one graph and joins them when it drains, these workers live for the
//! whole server lifetime and loop over the active-job set: pick a job
//! (random rotation — cheap, and admission already shaped the set),
//! `gettask` from it, execute via the shared `exec_task_guarded` path
//! in `coordinator/exec.rs`, and finalize the job whose last task they
//! completed. Per-run and per-server
//! execution therefore share one code path; only worker *lifetime* and
//! job multiplexing differ.
//!
//! [`run_virtual`] is the virtual-time variant: the same multi-job
//! serving discipline driven as a deterministic discrete-event
//! simulation (cf. `coordinator/sim.rs`), used by the reproducible
//! fairness tests.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::exec::exec_task_guarded;
use crate::coordinator::{CostModel, Scheduler, SimCtx};
use crate::util::rng::Rng;

use super::admission::FairQueue;
use super::protocol::{JobId, TenantId};
use super::registry::{ExecFn, JobGraph};

/// One admitted job being served by the pool. All counters are owned by
/// the pool's workers; the server reads them at finalization.
pub struct ActiveJob {
    pub id: JobId,
    pub tenant: TenantId,
    pub sched: Arc<Scheduler>,
    pub exec: ExecFn,
    /// Template name when the instance belongs to the registry pool.
    pub template: Option<String>,
    /// The template's declared kernel binding, when it has one
    /// (carried so checkin can hand the full instance back).
    pub kernels: Option<Arc<crate::coordinator::KernelRegistry<'static>>>,
    pub reused: bool,
    pub setup_ns: u64,
    pub queue_ns: u64,
    /// When the job was handed to the pool (service-time origin).
    pub started: Instant,
    pub tasks_run: AtomicU64,
    pub tasks_stolen: AtomicU64,
    pub exec_ns: AtomicU64,
    /// Set when a task function panicked (or the job failed to start).
    pub failed: AtomicBool,
    finalized: AtomicBool,
    /// Submission order is submit → `start()` → `mark_ready()`; workers
    /// skip (and never finalize) jobs not yet marked ready. Inserting
    /// into the active list *before* `start()` guarantees the list
    /// always names the current owner of a scheduler instance by the
    /// time its tasks are acquirable — the stale-handle guard in
    /// `worker_loop` relies on this.
    ready: AtomicBool,
}

impl ActiveJob {
    pub fn new(
        id: JobId,
        tenant: TenantId,
        graph: JobGraph,
        reused: bool,
        setup_ns: u64,
        queue_ns: u64,
    ) -> Arc<Self> {
        Arc::new(Self {
            id,
            tenant,
            sched: graph.sched,
            exec: graph.exec,
            template: graph.template,
            kernels: graph.kernels,
            reused,
            setup_ns,
            queue_ns,
            started: Instant::now(),
            tasks_run: AtomicU64::new(0),
            tasks_stolen: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            failed: AtomicBool::new(false),
            finalized: AtomicBool::new(false),
            ready: AtomicBool::new(false),
        })
    }

    /// Open the job to the workers; call after `start()` succeeded (or
    /// after setting `failed` when it did not).
    pub fn mark_ready(&self) {
        self.ready.store(true, Ordering::Release);
    }

    fn is_ready(&self) -> bool {
        self.ready.load(Ordering::Acquire)
    }
}

/// Called exactly once per job, from the worker that finalized it.
pub type OnFinish = Box<dyn Fn(Arc<ActiveJob>) + Send + Sync>;

struct Shared {
    jobs: Mutex<Vec<Arc<ActiveJob>>>,
    /// Bumped on every insert/removal so workers can reuse their
    /// snapshot of `jobs` instead of cloning it on every acquisition.
    generation: AtomicU64,
    cv: Condvar,
    shutdown: AtomicBool,
    on_finish: OnFinish,
    seed: u64,
}

/// Long-lived worker threads multiplexing over active jobs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    nr_workers: usize,
}

impl WorkerPool {
    pub fn start(nr_workers: usize, seed: u64, on_finish: OnFinish) -> Self {
        assert!(nr_workers > 0, "need at least one worker");
        let shared = Arc::new(Shared {
            jobs: Mutex::new(Vec::new()),
            generation: AtomicU64::new(0),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            on_finish,
            seed,
        });
        let handles = (0..nr_workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qs-pool-{wid}"))
                    .spawn(move || worker_loop(&shared, wid))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, handles, nr_workers }
    }

    pub fn nr_workers(&self) -> usize {
        self.nr_workers
    }

    /// Insert an admitted job. Contract: `submit` first, then `start()`
    /// its scheduler, then [`ActiveJob::mark_ready`] — workers ignore
    /// the job until it is ready, and the insert-before-start order
    /// keeps the active list authoritative for stale-handle resolution.
    pub fn submit(&self, job: Arc<ActiveJob>) {
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            jobs.push(job);
        }
        self.shared.generation.fetch_add(1, Ordering::AcqRel);
        self.shared.cv.notify_all();
    }

    /// Number of jobs currently being served (racy snapshot).
    pub fn active_jobs(&self) -> usize {
        self.shared.jobs.lock().unwrap().len()
    }

    fn stop(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop();
    }
}

fn try_finalize(shared: &Shared, job: &Arc<ActiveJob>) {
    if job.finalized.swap(true, Ordering::AcqRel) {
        return;
    }
    {
        let mut jobs = shared.jobs.lock().unwrap();
        jobs.retain(|j| !Arc::ptr_eq(j, job));
    }
    shared.generation.fetch_add(1, Ordering::AcqRel);
    (shared.on_finish)(Arc::clone(job));
}

fn worker_loop(shared: &Shared, wid: usize) {
    let mut rng = Rng::new(shared.seed ^ (wid as u64).wrapping_mul(0x9E3779B97F4A7C15));
    // Cached snapshot of the active-job list, refreshed only when the
    // generation counter moves (one Vec clone per membership change,
    // not per task acquisition).
    let mut jobs: Vec<Arc<ActiveJob>> = Vec::new();
    const STALE: u64 = u64::MAX;
    let mut seen_gen: u64 = STALE;
    let mut dry_scans: u32 = 0;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let gen = shared.generation.load(Ordering::Acquire);
        if gen != seen_gen {
            jobs = shared.jobs.lock().unwrap().clone();
            seen_gen = gen;
        }
        if jobs.is_empty() {
            let guard = shared.jobs.lock().unwrap();
            if guard.is_empty() && !shared.shutdown.load(Ordering::Acquire) {
                // Timeout bounds shutdown latency; submits notify.
                let _ = shared
                    .cv
                    .wait_timeout(guard, Duration::from_millis(5))
                    .unwrap();
            }
            seen_gen = STALE;
            continue;
        }
        let n = jobs.len();
        let start = if n > 1 { rng.index(n) } else { 0 };
        let mut ran = false;
        for k in 0..n {
            let job = &jobs[(start + k) % n];
            if !job.is_ready() || job.finalized.load(Ordering::Acquire) {
                continue;
            }
            if job.sched.waiting() <= 0 {
                // All tasks done but nobody finalized it yet (possible
                // when the last completion raced with job turnover) —
                // or a zero-task graph: finalize from the scan.
                try_finalize(shared, job);
                continue;
            }
            if job.sched.queued_hint() == 0 {
                continue;
            }
            let qid = wid % job.sched.nr_queues();
            if let Some((tid, stolen)) = job.sched.gettask(qid, &mut rng) {
                ran = true;
                // Stale-handle guard: this snapshot entry may belong to
                // a *previous* job of a reused scheduler instance. If
                // the job finalized (checked after gettask — finalize →
                // checkin → start → enqueue → gettask is a happens-
                // before chain through the queue lock), the acquired
                // task belongs to the instance's current owner in the
                // authoritative list; account everything there.
                let owner: Arc<ActiveJob> = if job.finalized.load(Ordering::Acquire) {
                    shared
                        .jobs
                        .lock()
                        .unwrap()
                        .iter()
                        .find(|j| Arc::ptr_eq(&j.sched, &job.sched))
                        .map(Arc::clone)
                        // No current owner: a leftover task of a failed,
                        // already-reported job — account to it; nothing
                        // reads the counters again.
                        .unwrap_or_else(|| Arc::clone(job))
                } else {
                    Arc::clone(job)
                };
                let (exec_ns, panicked) =
                    exec_task_guarded(&owner.sched, tid, owner.exec.as_ref());
                // All per-job accounting lands *before* complete(): the
                // completion may let another worker finalize the job,
                // and the report must already include this task.
                owner.tasks_run.fetch_add(1, Ordering::Relaxed);
                if stolen {
                    owner.tasks_stolen.fetch_add(1, Ordering::Relaxed);
                }
                owner.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
                if panicked {
                    owner.failed.store(true, Ordering::Release);
                }
                owner.sched.complete(tid);
                if panicked || owner.sched.waiting() <= 0 {
                    try_finalize(shared, &owner);
                }
                // Membership changes bump `generation`, so the cached
                // snapshot refreshes automatically next iteration.
                break;
            }
        }
        if ran {
            dry_scans = 0;
        } else {
            // Active jobs exist but nothing was ready: let task holders
            // progress (single-core testbed); after many dry scans back
            // off to a short sleep so idle workers stop burning a core
            // while one long task runs.
            dry_scans += 1;
            if dry_scans >= 256 {
                std::thread::sleep(Duration::from_micros(200));
            } else {
                std::thread::yield_now();
            }
        }
    }
}

// ----------------------------------------------------------------------
// Virtual-time pool
// ----------------------------------------------------------------------

/// A job for the virtual-time pool: a prepared scheduler arriving at a
/// virtual instant. (No execution function — durations come from the
/// [`CostModel`], exactly like `coordinator/sim.rs`.)
pub struct VirtualJob {
    pub tenant: TenantId,
    pub arrival_ns: u64,
    pub sched: Arc<Scheduler>,
}

/// Completion record of one virtual job.
#[derive(Clone, Copy, Debug)]
pub struct VirtualReport {
    pub job_index: usize,
    pub tenant: TenantId,
    pub arrival_ns: u64,
    pub admitted_ns: u64,
    pub finished_ns: u64,
    pub tasks_run: usize,
}

/// Event in the virtual-time queue. Field order gives the deterministic
/// tie-break: time, then kind (arrivals before completions), then core /
/// job / task.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Event {
    ns: u64,
    kind: u8, // 0 = arrival, 1 = task completion
    core: usize,
    job: usize,
    tid: u32,
}

const EV_ARRIVAL: u8 = 0;
const EV_DONE: u8 = 1;

/// Serve `jobs` on `nr_cores` virtual cores with at most `max_inflight`
/// jobs active, admission ordered by the weighted-fair queue
/// ([`FairQueue`]) under `weights`. Deterministic for a given input +
/// seed; returns one report per job (submission order).
pub fn run_virtual<M: CostModel>(
    jobs: Vec<VirtualJob>,
    weights: &[(TenantId, u64)],
    nr_cores: usize,
    max_inflight: usize,
    seed: u64,
    model: &M,
) -> Vec<VirtualReport> {
    assert!(nr_cores > 0);
    let mut admission: FairQueue<usize> = FairQueue::new(max_inflight);
    for &(t, w) in weights {
        admission.set_weight(t, w);
    }
    let mut rng = Rng::new(seed);
    let mut events: BinaryHeap<std::cmp::Reverse<Event>> = BinaryHeap::new();
    for (j, job) in jobs.iter().enumerate() {
        events.push(std::cmp::Reverse(Event {
            ns: job.arrival_ns,
            kind: EV_ARRIVAL,
            core: 0,
            job: j,
            tid: 0,
        }));
    }
    let mut busy = vec![false; nr_cores];
    let mut active_cores = 0usize;
    let mut running: Vec<usize> = Vec::new(); // job indices, admission order
    let mut reports: Vec<VirtualReport> = jobs
        .iter()
        .enumerate()
        .map(|(j, job)| VirtualReport {
            job_index: j,
            tenant: job.tenant,
            arrival_ns: job.arrival_ns,
            admitted_ns: u64::MAX,
            finished_ns: u64::MAX,
            tasks_run: 0,
        })
        .collect();
    let mut now = 0u64;

    // Admit as many queued jobs as slots allow at virtual time `now`.
    // Defined as a macro-free helper via closure-over-state is painful in
    // rust; use a small fn with explicit state instead.
    fn admit(
        admission: &mut FairQueue<usize>,
        jobs: &[VirtualJob],
        running: &mut Vec<usize>,
        reports: &mut [VirtualReport],
        now: u64,
    ) {
        while let Some((_tenant, j)) = admission.try_admit() {
            let sched = &jobs[j].sched;
            sched
                .reset_run()
                .and_then(|_| sched.start())
                .expect("virtual job must be prepared");
            reports[j].admitted_ns = now;
            if sched.waiting() == 0 {
                // Degenerate zero-task graph: completes instantly.
                reports[j].finished_ns = now;
                admission.finish(jobs[j].tenant);
                continue;
            }
            running.push(j);
        }
    }

    loop {
        // Dispatch phase: each idle core scans the running jobs once,
        // starting at a core-dependent rotation for spread.
        if !running.is_empty() {
            for core in 0..nr_cores {
                if busy[core] {
                    continue;
                }
                let nr = running.len();
                'jobs: for k in 0..nr {
                    let j = running[(core + k) % nr];
                    let sched = &jobs[j].sched;
                    if sched.queued_hint() == 0 {
                        continue 'jobs;
                    }
                    let qid = core % sched.nr_queues();
                    if let Some((tid, stolen)) = sched.gettask(qid, &mut rng) {
                        let view = sched.task_view(tid);
                        active_cores += 1;
                        let ctx = SimCtx { now_ns: now, active_cores, nr_cores };
                        let get_ns = model.gettask_overhead_ns(view, stolen);
                        let dur = model.duration_ns(view, &ctx).max(1);
                        busy[core] = true;
                        reports[j].tasks_run += 1;
                        events.push(std::cmp::Reverse(Event {
                            ns: now + get_ns + dur,
                            kind: EV_DONE,
                            core,
                            job: j,
                            tid: tid.0,
                        }));
                        break 'jobs;
                    }
                }
            }
        }
        match events.pop() {
            None => break,
            Some(std::cmp::Reverse(ev)) => {
                now = ev.ns;
                match ev.kind {
                    EV_ARRIVAL => {
                        admission.push(jobs[ev.job].tenant, ev.job);
                        admit(&mut admission, &jobs, &mut running, &mut reports, now);
                    }
                    _ => {
                        busy[ev.core] = false;
                        active_cores -= 1;
                        let sched = &jobs[ev.job].sched;
                        sched.complete(crate::coordinator::TaskId(ev.tid));
                        if sched.waiting() == 0 {
                            reports[ev.job].finished_ns = now;
                            running.retain(|&j| j != ev.job);
                            admission.finish(jobs[ev.job].tenant);
                            admit(&mut admission, &jobs, &mut running, &mut reports, now);
                        }
                    }
                }
            }
        }
    }
    debug_assert!(
        reports.iter().all(|r| r.finished_ns != u64::MAX),
        "virtual pool left jobs unfinished"
    );
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GraphBuilder, SchedConfig, UnitCost};
    use crate::server::registry::{synthetic_template, Registry};

    fn chain_job(tenant: u32, arrival: u64, n: usize, cost: i64) -> VirtualJob {
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let mut prev = None;
        for _ in 0..n {
            prev = Some(s.task(0).cost(cost).after(prev).spawn());
        }
        s.prepare().unwrap();
        VirtualJob { tenant: TenantId(tenant), arrival_ns: arrival, sched: Arc::new(s) }
    }

    #[test]
    fn virtual_pool_serves_single_job() {
        let jobs = vec![chain_job(0, 0, 10, 100)];
        let reps = run_virtual(jobs, &[], 2, 2, 1, &UnitCost);
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].tasks_run, 10);
        assert_eq!(reps[0].admitted_ns, 0);
        assert!(reps[0].finished_ns >= 1000, "chain of 10x100 is serial");
    }

    #[test]
    fn virtual_pool_bounded_inflight_serializes() {
        // 4 serial-chain jobs, 1 in-flight slot: jobs must not overlap —
        // each admission waits for the previous finish.
        let jobs: Vec<VirtualJob> = (0..4).map(|_| chain_job(0, 0, 5, 50)).collect();
        let reps = run_virtual(jobs, &[], 4, 1, 1, &UnitCost);
        let mut spans: Vec<(u64, u64)> =
            reps.iter().map(|r| (r.admitted_ns, r.finished_ns)).collect();
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[1].0 >= w[0].1, "jobs overlapped under max_inflight=1: {spans:?}");
        }
        // Each chain is serial: 5 tasks × (50 + 250 gettask overhead).
        for (a, f) in &spans {
            assert_eq!(f - a, 5 * 300, "chain service time");
        }
    }

    #[test]
    fn virtual_pool_is_deterministic() {
        let mk = || {
            let jobs: Vec<VirtualJob> = (0..6)
                .map(|i| chain_job(i % 2, (i as u64) * 10, 8, 30))
                .collect();
            run_virtual(jobs, &[(TenantId(0), 1), (TenantId(1), 1)], 3, 2, 42, &UnitCost)
                .iter()
                .map(|r| (r.admitted_ns, r.finished_ns, r.tasks_run))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn threaded_pool_drains_jobs() {
        use std::sync::mpsc;
        let reg = Registry::new(SchedConfig::new(2), 4);
        reg.register("syn", synthetic_template(60, 4, 5, 0));
        let (tx, rx) = mpsc::channel::<Arc<ActiveJob>>();
        let tx = Mutex::new(tx);
        let pool = WorkerPool::start(
            2,
            7,
            Box::new(move |job| {
                let _ = tx.lock().unwrap().send(job);
            }),
        );
        for i in 0..8u64 {
            let (g, reused) = reg.checkout("syn", true).unwrap();
            let job = ActiveJob::new(JobId(i), TenantId(0), g, reused, 0, 0);
            pool.submit(Arc::clone(&job));
            job.sched.start().unwrap();
            job.mark_ready();
            // Serialize via completion so instances can be reused: wait
            // for this job before submitting the next.
            let done = rx.recv_timeout(Duration::from_secs(30)).expect("job finished");
            assert_eq!(done.id, JobId(i));
            assert!(!done.failed.load(Ordering::Acquire));
            assert_eq!(done.tasks_run.load(Ordering::Relaxed), 60);
            assert!(done.sched.resources().all_quiescent());
            reg.checkin(JobGraph {
                sched: Arc::clone(&done.sched),
                exec: Arc::clone(&done.exec),
                template: done.template.clone(),
                kernels: done.kernels.clone(),
            });
        }
        let c = reg.counters("syn").unwrap();
        assert_eq!(c.builds, 1, "all 8 jobs served by one built instance");
        assert_eq!(c.reuses, 7);
        pool.shutdown();
    }

    #[test]
    fn threaded_pool_concurrent_jobs() {
        use std::sync::mpsc;
        let reg = Registry::new(SchedConfig::new(2), 8);
        reg.register("syn", synthetic_template(40, 3, 9, 0));
        let (tx, rx) = mpsc::channel::<Arc<ActiveJob>>();
        let tx = Mutex::new(tx);
        let pool = WorkerPool::start(
            2,
            13,
            Box::new(move |job| {
                let _ = tx.lock().unwrap().send(job);
            }),
        );
        // 4 distinct instances active at once over one pool.
        for i in 0..4u64 {
            let (g, _) = reg.checkout("syn", false).unwrap();
            let job = ActiveJob::new(JobId(i), TenantId(i as u32 % 2), g, false, 0, 0);
            pool.submit(Arc::clone(&job));
            job.sched.start().unwrap();
            job.mark_ready();
        }
        let mut seen = Vec::new();
        for _ in 0..4 {
            let done = rx.recv_timeout(Duration::from_secs(30)).expect("job finished");
            assert_eq!(done.tasks_run.load(Ordering::Relaxed), 40);
            seen.push(done.id.0);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        pool.shutdown();
    }
}
