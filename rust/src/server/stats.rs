//! Per-tenant service statistics: completed/failed jobs, task counts,
//! setup-cost split by template reuse, and latency percentiles (reusing
//! the crate's own summary machinery, `util::stats`). The `bench-server`
//! JSON trajectory (`BENCH_server.json`) is rendered from a
//! [`StatsSnapshot`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::percentile_sorted;

use super::protocol::{JobReport, TenantId};

/// Bounded sample buffer: a ring over the most recent
/// [`MAX_SAMPLES`] observations, so a long-lived server's stats stay
/// O(1) in memory and snapshot cost while counters remain exact.
#[derive(Clone, Debug, Default)]
struct Samples {
    xs: Vec<f64>,
    cursor: usize,
}

/// Per-metric retention window (recent jobs; percentiles and means are
/// computed over this window, counts over the full lifetime).
const MAX_SAMPLES: usize = 4096;

impl Samples {
    fn push(&mut self, x: f64) {
        if self.xs.len() < MAX_SAMPLES {
            self.xs.push(x);
        } else {
            self.xs[self.cursor] = x;
            self.cursor = (self.cursor + 1) % MAX_SAMPLES;
        }
    }

    fn as_slice(&self) -> &[f64] {
        &self.xs
    }
}

#[derive(Clone, Debug, Default)]
struct TenantAcc {
    /// Global tick of the most recent `record`/`record_failure` touch;
    /// the LRU key for row eviction (see [`MAX_TENANT_ROWS`]).
    touch: u64,
    completed: u64,
    failed: u64,
    tasks_run: u64,
    tasks_stolen: u64,
    reused: u64,
    built: u64,
    setup_reuse_ns: Samples,
    setup_build_ns: Samples,
    total_ns: Samples,
    service_ns: Samples,
    queue_ns: Samples,
    dispatch_ns: Samples,
    /// Sum over completed jobs of `batched_with` (fused batch sizes).
    batched_with: u64,
}

/// Aggregated view of one tenant.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub tenant: TenantId,
    pub completed: u64,
    pub failed: u64,
    pub tasks_run: u64,
    pub tasks_stolen: u64,
    /// Jobs served from the template instance pool / via fresh builds.
    pub reused: u64,
    pub built: u64,
    /// Mean setup cost on the two paths, ns (0 when unobserved; means
    /// and percentiles cover the most recent `MAX_SAMPLES` jobs).
    pub mean_setup_reuse_ns: f64,
    pub mean_setup_build_ns: f64,
    /// End-to-end latency percentiles, ns.
    pub p50_total_ns: f64,
    pub p90_total_ns: f64,
    pub mean_service_ns: f64,
    pub mean_queue_ns: f64,
    /// Mean amortized per-job dispatch overhead (admission sweep /
    /// batch size), ns — the fused-vs-unfused comparison quantity.
    pub mean_dispatch_ns: f64,
    /// Mean activation-batch size over completed jobs (1.0 = never
    /// fused).
    pub mean_batched_with: f64,
}

/// Number of buckets in the admission-sweep width histogram: bucket
/// `i` counts sweeps that fused `i + 1` jobs, the last bucket counting
/// `>= BATCH_BUCKETS`.
pub const BATCH_BUCKETS: usize = 16;

/// Default cap on live per-tenant rows. A long-lived listener sees a
/// `TenantAcc` allocated for every tenant id any Hello ever declared;
/// without a bound a hostile (or merely churny) client population grows
/// the table — and every `snapshot()` — without limit. Past the cap the
/// least-recently-touched row is evicted and counted in
/// [`StatsSnapshot::evicted_tenants`].
pub const MAX_TENANT_ROWS: usize = 256;

/// Snapshot of the whole server.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    pub uptime_s: f64,
    /// Histogram of chosen/realized admission-sweep widths (the K each
    /// sweep actually fused — the observable of adaptive batching).
    /// `batch_hist[i]` = sweeps of width `i + 1`; last bucket is
    /// `>= BATCH_BUCKETS`.
    pub batch_hist: Vec<u64>,
    /// Tenant rows evicted by the LRU cap ([`MAX_TENANT_ROWS`]) over the
    /// server's lifetime. Non-zero means per-tenant counters below are
    /// an undercount for the evicted tenants.
    pub evicted_tenants: u64,
    pub tenants: Vec<TenantSummary>,
}

impl StatsSnapshot {
    pub fn completed(&self) -> u64 {
        self.tenants.iter().map(|t| t.completed).sum()
    }

    pub fn jobs_per_sec(&self) -> f64 {
        if self.uptime_s <= 0.0 {
            0.0
        } else {
            self.completed() as f64 / self.uptime_s
        }
    }

    /// Render as a fixed-width text table.
    pub fn render(&self) -> String {
        let mut t = crate::bench::harness::Table::new(&[
            "tenant", "done", "failed", "tasks", "reused", "built", "setup_reuse_us",
            "setup_build_us", "p50_ms", "p90_ms",
        ]);
        for s in &self.tenants {
            t.row(&[
                s.tenant.to_string(),
                s.completed.to_string(),
                s.failed.to_string(),
                s.tasks_run.to_string(),
                s.reused.to_string(),
                s.built.to_string(),
                format!("{:.1}", s.mean_setup_reuse_ns / 1e3),
                format!("{:.1}", s.mean_setup_build_ns / 1e3),
                format!("{:.3}", s.p50_total_ns / 1e6),
                format!("{:.3}", s.p90_total_ns / 1e6),
            ]);
        }
        let mut widths = String::new();
        for (i, &n) in self.batch_hist.iter().enumerate() {
            if n > 0 {
                widths.push_str(&format!(" {}:{}", i + 1, n));
            }
        }
        if widths.is_empty() {
            widths.push_str(" -");
        }
        format!(
            "{}\ntotal: {} jobs in {:.2}s = {:.1} jobs/s\nsweep widths (K:count):{}\n",
            t.render(),
            self.completed(),
            self.uptime_s,
            self.jobs_per_sec(),
            widths
        )
    }

    /// Hand-rolled JSON (no serde in the offline registry).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"uptime_s\": {:.6},\n", self.uptime_s));
        out.push_str(&format!("  \"jobs_completed\": {},\n", self.completed()));
        out.push_str(&format!("  \"jobs_per_sec\": {:.3},\n", self.jobs_per_sec()));
        let hist: Vec<String> = self.batch_hist.iter().map(|n| n.to_string()).collect();
        out.push_str(&format!("  \"batch_hist\": [{}],\n", hist.join(", ")));
        out.push_str(&format!(
            "  \"evicted_tenants\": {},\n",
            self.evicted_tenants
        ));
        out.push_str("  \"tenants\": [\n");
        for (i, s) in self.tenants.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"tenant\": {}, \"completed\": {}, \"failed\": {}, \
                 \"tasks_run\": {}, \"tasks_stolen\": {}, \"reused\": {}, \"built\": {}, \
                 \"mean_setup_reuse_ns\": {:.1}, \"mean_setup_build_ns\": {:.1}, \
                 \"p50_total_ns\": {:.1}, \"p90_total_ns\": {:.1}, \
                 \"mean_service_ns\": {:.1}, \"mean_queue_ns\": {:.1}, \
                 \"mean_dispatch_ns\": {:.1}, \"mean_batched_with\": {:.2}}}{}",
                s.tenant.0,
                s.completed,
                s.failed,
                s.tasks_run,
                s.tasks_stolen,
                s.reused,
                s.built,
                s.mean_setup_reuse_ns,
                s.mean_setup_build_ns,
                s.p50_total_ns,
                s.p90_total_ns,
                s.mean_service_ns,
                s.mean_queue_ns,
                s.mean_dispatch_ns,
                s.mean_batched_with,
                if i + 1 == self.tenants.len() { "\n" } else { ",\n" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// The mutex-guarded tenant table: the rows plus the LRU bookkeeping
/// that bounds them.
#[derive(Debug, Default)]
struct TenantTable {
    map: BTreeMap<TenantId, TenantAcc>,
    /// Monotone touch clock; every row access stamps `TenantAcc::touch`.
    tick: u64,
    /// Live-row cap (default [`MAX_TENANT_ROWS`]).
    cap: usize,
    /// Lifetime count of rows evicted at the cap.
    evicted: u64,
}

impl TenantTable {
    /// Fetch-or-insert the row for `tenant`, stamping its touch tick and
    /// evicting the least-recently-touched row first if the insert would
    /// exceed the cap.
    fn acc(&mut self, tenant: TenantId) -> &mut TenantAcc {
        self.tick += 1;
        let tick = self.tick;
        if !self.map.contains_key(&tenant) {
            while self.map.len() >= self.cap.max(1) {
                let victim = self
                    .map
                    .iter()
                    .min_by_key(|(_, a)| a.touch)
                    .map(|(&id, _)| id);
                match victim {
                    Some(id) => {
                        self.map.remove(&id);
                        self.evicted += 1;
                    }
                    None => break,
                }
            }
        }
        let acc = self.map.entry(tenant).or_default();
        acc.touch = tick;
        acc
    }
}

/// Thread-safe accumulator the server records every [`JobReport`] into.
pub struct ServerStats {
    tenants: Mutex<TenantTable>,
    /// Admission-sweep width histogram (see [`BATCH_BUCKETS`]).
    sweeps: Mutex<[u64; BATCH_BUCKETS]>,
    /// Core-scheduler hot-path counters `[gettask_calls, gettask_hits,
    /// gettask_steals, acquire_attempts, acquire_failures]`: per-job
    /// deltas of `Scheduler::obs_counters`, folded in at finalization
    /// (deltas, because pooled template instances carry their counters
    /// across jobs).
    sched_obs: [AtomicU64; 5],
    started: Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    pub fn new() -> Self {
        Self {
            tenants: Mutex::new(TenantTable {
                cap: MAX_TENANT_ROWS,
                ..TenantTable::default()
            }),
            sweeps: Mutex::new([0; BATCH_BUCKETS]),
            sched_obs: Default::default(),
            started: Instant::now(),
        }
    }

    /// Fold one finished job's core-scheduler counter deltas in (same
    /// order as [`ServerStats::sched_obs`]).
    pub fn add_sched_obs(&self, delta: [u64; 5]) {
        for (slot, d) in self.sched_obs.iter().zip(delta) {
            slot.fetch_add(d, Ordering::Relaxed);
        }
    }

    /// Aggregated core-scheduler counters over finished jobs:
    /// `[gettask_calls, gettask_hits, gettask_steals, acquire_attempts,
    /// acquire_failures]`.
    pub fn sched_obs(&self) -> [u64; 5] {
        std::array::from_fn(|i| self.sched_obs[i].load(Ordering::Relaxed))
    }

    /// Override the live-row cap (tests and memory-constrained deploys;
    /// clamped to >= 1). Existing rows above the new cap are evicted
    /// lazily as new tenants arrive.
    pub fn set_row_cap(&self, cap: usize) {
        self.tenants.lock().unwrap().cap = cap.max(1);
    }

    /// Lifetime count of tenant rows evicted by the LRU cap.
    pub fn evicted_tenants(&self) -> u64 {
        self.tenants.lock().unwrap().evicted
    }

    /// Record one admission sweep that fused `k` jobs (k ≥ 1).
    pub fn record_sweep(&self, k: usize) {
        let idx = k.clamp(1, BATCH_BUCKETS) - 1;
        self.sweeps.lock().unwrap()[idx] += 1;
    }

    pub fn record(&self, r: &JobReport) {
        let mut table = self.tenants.lock().unwrap();
        let acc = table.acc(r.tenant);
        acc.completed += 1;
        acc.tasks_run += r.tasks_run as u64;
        acc.tasks_stolen += r.tasks_stolen as u64;
        if r.reused_template {
            acc.reused += 1;
            acc.setup_reuse_ns.push(r.setup_ns as f64);
        } else {
            acc.built += 1;
            acc.setup_build_ns.push(r.setup_ns as f64);
        }
        acc.total_ns.push(r.total_ns() as f64);
        acc.service_ns.push(r.service_ns as f64);
        acc.queue_ns.push(r.queue_ns as f64);
        acc.dispatch_ns.push(r.dispatch_ns as f64);
        acc.batched_with += r.batched_with.max(1) as u64;
    }

    pub fn record_failure(&self, tenant: TenantId) {
        let mut table = self.tenants.lock().unwrap();
        table.acc(tenant).failed += 1;
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let table = self.tenants.lock().unwrap();
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let pct = |xs: &[f64], p: f64| {
            if xs.is_empty() {
                0.0
            } else {
                let mut s = xs.to_vec();
                s.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
                percentile_sorted(&s, p)
            }
        };
        let tenants = table
            .map
            .iter()
            .map(|(&tenant, a)| TenantSummary {
                tenant,
                completed: a.completed,
                failed: a.failed,
                tasks_run: a.tasks_run,
                tasks_stolen: a.tasks_stolen,
                reused: a.reused,
                built: a.built,
                mean_setup_reuse_ns: mean(a.setup_reuse_ns.as_slice()),
                mean_setup_build_ns: mean(a.setup_build_ns.as_slice()),
                p50_total_ns: pct(a.total_ns.as_slice(), 50.0),
                p90_total_ns: pct(a.total_ns.as_slice(), 90.0),
                mean_service_ns: mean(a.service_ns.as_slice()),
                mean_queue_ns: mean(a.queue_ns.as_slice()),
                mean_dispatch_ns: mean(a.dispatch_ns.as_slice()),
                mean_batched_with: if a.completed == 0 {
                    0.0
                } else {
                    a.batched_with as f64 / a.completed as f64
                },
            })
            .collect();
        StatsSnapshot {
            uptime_s: self.started.elapsed().as_secs_f64(),
            batch_hist: self.sweeps.lock().unwrap().to_vec(),
            evicted_tenants: table.evicted,
            tenants,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::JobId;

    fn report(tenant: u32, setup: u64, reused: bool, service: u64) -> JobReport {
        JobReport {
            job: JobId(0),
            tenant: TenantId(tenant),
            tasks_run: 10,
            tasks_stolen: 1,
            exec_ns: 100,
            queue_ns: 50,
            setup_ns: setup,
            service_ns: service,
            dispatch_ns: 40,
            batched_with: 2,
            reused_template: reused,
        }
    }

    #[test]
    fn records_split_by_reuse() {
        let s = ServerStats::new();
        s.record(&report(0, 1000, false, 500));
        s.record(&report(0, 10, true, 500));
        s.record(&report(0, 20, true, 700));
        let snap = s.snapshot();
        assert_eq!(snap.tenants.len(), 1);
        let t = &snap.tenants[0];
        assert_eq!(t.completed, 3);
        assert_eq!((t.reused, t.built), (2, 1));
        assert!((t.mean_setup_reuse_ns - 15.0).abs() < 1e-9);
        assert!((t.mean_setup_build_ns - 1000.0).abs() < 1e-9);
        assert_eq!(t.tasks_run, 30);
        assert!((t.mean_dispatch_ns - 40.0).abs() < 1e-9);
        assert!((t.mean_batched_with - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sample_window_is_bounded_counts_exact() {
        let s = ServerStats::new();
        for i in 0..(MAX_SAMPLES + 100) {
            s.record(&report(0, i as u64, true, 1));
        }
        let snap = s.snapshot();
        let t = &snap.tenants[0];
        // Lifetime counters stay exact...
        assert_eq!(t.completed as usize, MAX_SAMPLES + 100);
        // ...while means cover exactly the most recent MAX_SAMPLES jobs:
        // setup values 100..=MAX_SAMPLES+99 -> mean (100 + 4195) / 2.
        let want = (100.0 + (MAX_SAMPLES + 99) as f64) / 2.0;
        assert!(
            (t.mean_setup_reuse_ns - want).abs() < 1e-9,
            "ring window mean {} != {want}",
            t.mean_setup_reuse_ns
        );
    }

    #[test]
    fn failures_counted() {
        let s = ServerStats::new();
        s.record_failure(TenantId(2));
        let snap = s.snapshot();
        assert_eq!(snap.tenants[0].failed, 1);
        assert_eq!(snap.completed(), 0);
    }

    #[test]
    fn json_and_table_render() {
        let s = ServerStats::new();
        s.record(&report(0, 100, true, 200));
        s.record(&report(1, 900, false, 300));
        s.record_sweep(2);
        let snap = s.snapshot();
        let json = snap.to_json();
        assert!(json.contains("\"tenants\": ["));
        assert!(json.contains("\"completed\": 1"));
        assert!(json.contains("\"batch_hist\": [0, 1, 0"));
        assert!(json.trim_end().ends_with('}'));
        let table = snap.render();
        assert!(table.contains("tenant0"));
        assert!(table.contains("jobs/s"));
        assert!(table.contains("sweep widths"));
        assert!(table.contains("2:1"));
    }

    #[test]
    fn tenant_rows_are_lru_capped() {
        let s = ServerStats::new();
        s.set_row_cap(3);
        for t in 0..3 {
            s.record(&report(t, 1, true, 1));
        }
        // Touch tenant 0 again so tenant 1 becomes the LRU victim.
        s.record(&report(0, 1, true, 1));
        s.record(&report(3, 1, true, 1));
        let snap = s.snapshot();
        assert_eq!(snap.evicted_tenants, 1);
        assert_eq!(s.evicted_tenants(), 1);
        let ids: Vec<u32> = snap.tenants.iter().map(|t| t.tenant.0).collect();
        assert_eq!(ids, vec![0, 2, 3], "LRU row (tenant 1) evicted");
        // Re-arrival after eviction starts a fresh row (undercount is
        // reported via evicted_tenants, not hidden).
        s.record(&report(1, 1, true, 1));
        let snap = s.snapshot();
        assert_eq!(snap.evicted_tenants, 2);
        let one = snap.tenants.iter().find(|t| t.tenant.0 == 1).unwrap();
        assert_eq!(one.completed, 1);
        assert!(snap.to_json().contains("\"evicted_tenants\": 2"));
    }

    #[test]
    fn failures_touch_lru_order_too() {
        let s = ServerStats::new();
        s.set_row_cap(2);
        s.record(&report(0, 1, true, 1));
        s.record(&report(1, 1, true, 1));
        s.record_failure(TenantId(0)); // tenant 1 is now LRU
        s.record(&report(2, 1, true, 1));
        let ids: Vec<u32> = s.snapshot().tenants.iter().map(|t| t.tenant.0).collect();
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn sweep_histogram_buckets() {
        let s = ServerStats::new();
        s.record_sweep(1);
        s.record_sweep(1);
        s.record_sweep(4);
        s.record_sweep(0); // clamped into bucket 1
        s.record_sweep(999); // clamped into the last bucket
        let snap = s.snapshot();
        assert_eq!(snap.batch_hist.len(), BATCH_BUCKETS);
        assert_eq!(snap.batch_hist[0], 3);
        assert_eq!(snap.batch_hist[3], 1);
        assert_eq!(snap.batch_hist[BATCH_BUCKETS - 1], 1);
    }
}
