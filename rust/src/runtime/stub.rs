//! Stub runtime used when the crate is built **without** the `xla`
//! feature (the default — the `xla` crate and its PJRT build are not in
//! the offline registry; see Cargo.toml).
//!
//! It mirrors the full [`super::service`]/[`super::backends`] API
//! surface exactly, so the CLI `--backend xla` paths, the
//! `examples/e2e_xla.rs` driver and the `rust/tests/xla_backend.rs`
//! suite all *compile* unchanged; anything that actually starts the
//! runtime gets a descriptive error at `RuntimeService::start` instead
//! of a link failure ("stub error path", DESIGN.md §Hardware-
//! substitutions).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::hlo::Manifest;
use crate::coordinator::{KernelRegistry, TaskView};
use crate::nbody::kernels::NBodyState;
use crate::nbody::tasks::NbTask;
use crate::qr::driver::TileBackend;

const DISABLED: &str = "PJRT runtime unavailable: this build has the `xla` cargo feature \
     disabled (the offline registry has no `xla` crate). Rebuild with \
     `--features xla` after adding the dependency — see Cargo.toml.";

/// A tensor crossing the service boundary: flat f64 data + shape.
/// (Same layout as the real service's type.)
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f64>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f64>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }

    pub fn vec(data: Vec<f64>) -> Self {
        let n = data.len();
        Self::new(data, vec![n])
    }
}

/// Handle to the (unavailable) executor pool.
pub struct RuntimeService {
    manifest: Manifest,
}

impl RuntimeService {
    /// Always fails in stub builds; the error explains how to enable the
    /// real runtime.
    pub fn start(manifest: Manifest, n_executors: usize) -> Result<Arc<Self>> {
        assert!(n_executors > 0);
        let _ = &manifest;
        Err(anyhow!(DISABLED))
    }

    /// Convenience: load the manifest from the default artifact dir.
    pub fn start_default(n_executors: usize) -> Result<Arc<Self>> {
        Self::start(Manifest::load(Manifest::default_dir())?, n_executors)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Unreachable in practice (`start` never succeeds), but kept so the
    /// callers typecheck identically against stub and real service.
    pub fn call(&self, _module: &str, _inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        Err(anyhow!(DISABLED))
    }
}

/// Stub of the XLA-backed QR tile backend.
pub struct XlaTileBackend {
    _svc: Arc<RuntimeService>,
}

impl XlaTileBackend {
    pub fn new(svc: Arc<RuntimeService>) -> Self {
        Self { _svc: svc }
    }
}

impl TileBackend for XlaTileBackend {
    fn geqrf(&self, _a: &mut [f64], _tau: &mut [f64], _b: usize) {
        panic!("{DISABLED}");
    }
    fn larft(&self, _v: &[f64], _tau: &[f64], _c: &mut [f64], _b: usize) {
        panic!("{DISABLED}");
    }
    fn tsqrt(&self, _r: &mut [f64], _a: &mut [f64], _tau: &mut [f64], _b: usize) {
        panic!("{DISABLED}");
    }
    fn ssrft(&self, _v2: &[f64], _tau: &[f64], _c_kj: &mut [f64], _c_ij: &mut [f64], _b: usize) {
        panic!("{DISABLED}");
    }
    fn name(&self) -> &'static str {
        "xla-stub"
    }
}

/// Stub of the XLA-backed N-body task executor.
pub struct XlaNbodyExec {
    _svc: Arc<RuntimeService>,
}

impl XlaNbodyExec {
    pub fn new(svc: Arc<RuntimeService>) -> Self {
        Self { _svc: svc }
    }

    /// API-equal stub of the real backend's kernel registry: all four
    /// task types bound, every kernel reports the disabled feature.
    /// (Unreachable in practice — `RuntimeService::start` never
    /// succeeds in stub builds.)
    pub fn registry<'a>(&'a self, state: &'a NBodyState) -> KernelRegistry<'a> {
        let _ = state;
        KernelRegistry::new()
            .bind(NbTask::SelfInteract, |_view: TaskView<'_>| panic!("{DISABLED}"))
            .bind(NbTask::PairPP, |_view: TaskView<'_>| panic!("{DISABLED}"))
            .bind(NbTask::PairPC, |_view: TaskView<'_>| panic!("{DISABLED}"))
            .bind(NbTask::Com, |_view: TaskView<'_>| panic!("{DISABLED}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(Tensor::vec(vec![5.0; 3]).shape, vec![3]);
    }

    #[test]
    fn start_reports_disabled_feature() {
        let err = RuntimeService::start(Manifest::default(), 1).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
