//! Artifact discovery: locate `artifacts/*.hlo.txt` and parse
//! `manifest.txt` (written by `python/compile/aot.py`), which records
//! each module's input shapes and output arity so the runtime can
//! marshal Literals without hard-coding shapes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Shape of one f64 input tensor.
pub type Shape = Vec<usize>;

/// One AOT-compiled module's interface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModuleInfo {
    pub name: String,
    pub inputs: Vec<Shape>,
    pub n_outputs: usize,
    pub path: PathBuf,
}

impl ModuleInfo {
    pub fn input_elems(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }
}

/// Parsed manifest: module name → interface.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub modules: HashMap<String, ModuleInfo>,
    pub dir: PathBuf,
}

/// Parse one `f64[a,b,...]` signature.
fn parse_shape(sig: &str) -> Result<Shape> {
    let sig = sig.trim();
    let inner = sig
        .strip_prefix("f64[")
        .and_then(|s| s.strip_suffix(']'))
        .with_context(|| format!("bad shape signature {sig:?}"))?;
    if inner.is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<usize>().context("bad dim"))
        .collect()
}

/// Split a signature list on commas *outside* brackets.
fn split_sigs(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut modules = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split(';');
            let (name, sig, n_out) = match (fields.next(), fields.next(), fields.next()) {
                (Some(a), Some(b), Some(c)) => (a, b, c),
                _ => bail!("manifest line {} malformed: {line:?}", lineno + 1),
            };
            let inputs = split_sigs(sig)
                .into_iter()
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()?;
            let hlo = dir.join(format!("{name}.hlo.txt"));
            if !hlo.exists() {
                bail!("manifest names {name} but {hlo:?} is missing");
            }
            modules.insert(
                name.to_string(),
                ModuleInfo {
                    name: name.to_string(),
                    inputs,
                    n_outputs: n_out.trim().parse().context("bad output count")?,
                    path: hlo,
                },
            );
        }
        Ok(Self { modules, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ModuleInfo> {
        self.modules
            .get(name)
            .with_context(|| format!("module {name:?} not in manifest ({} known)", self.modules.len()))
    }

    /// The default artifact directory: `$QS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("QS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shapes() {
        assert_eq!(parse_shape("f64[8,8]").unwrap(), vec![8, 8]);
        assert_eq!(parse_shape("f64[128]").unwrap(), vec![128]);
        assert_eq!(parse_shape("f64[]").unwrap(), Vec::<usize>::new());
        assert!(parse_shape("f32[8]").is_err());
    }

    #[test]
    fn split_respects_brackets() {
        assert_eq!(
            split_sigs("f64[8,8],f64[8],f64[2048,3]"),
            vec!["f64[8,8]", "f64[8]", "f64[2048,3]"]
        );
    }

    #[test]
    fn load_manifest_from_fixture() {
        let dir = std::env::temp_dir().join(format!("qs_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "HloModule foo").unwrap();
        std::fs::write(dir.join("manifest.txt"), "foo;f64[4,4],f64[4];2\n").unwrap();
        let m = Manifest::load(&dir).unwrap();
        let info = m.get("foo").unwrap();
        assert_eq!(info.inputs, vec![vec![4, 4], vec![4]]);
        assert_eq!(info.n_outputs, 2);
        assert_eq!(info.input_elems(0), 16);
        assert!(m.get("bar").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_hlo_rejected() {
        let dir = std::env::temp_dir().join(format!("qs_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "ghost;f64[2];1\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse and
        // cover the QR + N-body entry points.
        let dir = Manifest::default_dir();
        if !dir.join("manifest.txt").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["qr_geqrf_8", "qr_larft_64", "nb_self_128", "nb_pc_2048"] {
            assert!(m.get(name).is_ok(), "missing {name}");
        }
        let g = m.get("qr_geqrf_64").unwrap();
        assert_eq!(g.inputs, vec![vec![64, 64]]);
        assert_eq!(g.n_outputs, 2);
    }
}
