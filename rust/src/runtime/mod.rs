//! PJRT runtime: load AOT HLO artifacts (lowered from the Layer-1/2
//! Pallas+JAX code by `python/compile/aot.py`) and execute them from the
//! L3 hot path. `PjRtClient` is `Rc`-based (`!Send`), so all PJRT
//! objects live on dedicated executor threads behind channels
//! ([`service`]); [`backends`] adapts the two applications to it.
//!
//! The PJRT-dependent pieces are gated behind the `xla` cargo feature
//! (the `xla` crate is not in the offline registry). Default builds get
//! [`stub`]: the identical API surface with an error path at
//! `RuntimeService::start`, so the CLI, examples and tests compile and
//! degrade gracefully on machines without XLA artifacts.
pub mod hlo;

#[cfg(feature = "xla")]
pub mod backends;
#[cfg(feature = "xla")]
pub mod service;

#[cfg(not(feature = "xla"))]
pub mod stub;

pub use hlo::{Manifest, ModuleInfo};

#[cfg(feature = "xla")]
pub use backends::{XlaNbodyExec, XlaTileBackend};
#[cfg(feature = "xla")]
pub use service::{RuntimeService, Tensor};

#[cfg(not(feature = "xla"))]
pub use stub::{RuntimeService, Tensor, XlaNbodyExec, XlaTileBackend};
