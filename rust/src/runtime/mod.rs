//! PJRT runtime: load AOT HLO artifacts (lowered from the Layer-1/2
//! Pallas+JAX code by `python/compile/aot.py`) and execute them from the
//! L3 hot path. `PjRtClient` is `Rc`-based (`!Send`), so all PJRT
//! objects live on dedicated executor threads behind channels
//! ([`service`]); [`backends`] adapts the two applications to it.
pub mod backends;
pub mod hlo;
pub mod service;

pub use backends::{XlaNbodyExec, XlaTileBackend};
pub use hlo::{Manifest, ModuleInfo};
pub use service::{RuntimeService, Tensor};
