//! PJRT runtime service: executes AOT-compiled HLO modules from the L3
//! hot path.
//!
//! The published `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so
//! all PJRT objects are confined to dedicated **executor threads**, each
//! owning its own CPU client and lazily-compiled executable cache.
//! Scheduler workers submit [`Request`]s over an mpsc channel shared by
//! the executors (vLLM-router style: router threads never touch the
//! backend runtime directly) and block on a per-call reply channel.
//! Python is never involved: the artifacts were lowered once at build
//! time by `python/compile/aot.py`.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::hlo::Manifest;

/// A tensor crossing the service boundary: flat f64 data + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f64>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f64>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }

    pub fn vec(data: Vec<f64>) -> Self {
        let n = data.len();
        Self::new(data, vec![n])
    }
}

struct Request {
    module: String,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Handle to the executor pool. Cloneable and `Sync`; dropping the last
/// clone shuts the executors down.
pub struct RuntimeService {
    tx: Mutex<mpsc::Sender<Request>>,
    manifest: Manifest,
    handles: Vec<JoinHandle<()>>,
}

impl RuntimeService {
    /// Start `n_executors` PJRT executor threads over the artifact
    /// directory. Each thread compiles a module the first time it is
    /// asked to run it and caches the executable.
    pub fn start(manifest: Manifest, n_executors: usize) -> Result<Arc<Self>> {
        assert!(n_executors > 0);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for eid in 0..n_executors {
            let rx = Arc::clone(&rx);
            let manifest = manifest.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-exec-{eid}"))
                    .spawn(move || executor_loop(rx, manifest))
                    .context("spawning executor")?,
            );
        }
        Ok(Arc::new(Self { tx: Mutex::new(tx), manifest, handles }))
    }

    /// Convenience: load the manifest from the default artifact dir.
    pub fn start_default(n_executors: usize) -> Result<Arc<Self>> {
        Self::start(Manifest::load(Manifest::default_dir())?, n_executors)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute `module` with `inputs`; blocks until the result arrives.
    /// Thread-safe: any number of scheduler workers may call concurrently.
    pub fn call(&self, module: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let info = self.manifest.get(module)?;
        if inputs.len() != info.inputs.len() {
            return Err(anyhow!(
                "{module}: expected {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(&info.inputs).enumerate() {
            if &t.shape != s {
                return Err(anyhow!(
                    "{module}: input {i} shape {:?} != manifest {:?}",
                    t.shape,
                    s
                ));
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Request { module: module.to_string(), inputs, reply: reply_tx })
                .map_err(|_| anyhow!("runtime service is down"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the request"))?
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        // Closing the channel ends the executor loops.
        {
            let (dead_tx, _) = mpsc::channel();
            let mut guard = self.tx.lock().unwrap();
            *guard = dead_tx;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(rx: Arc<Mutex<mpsc::Receiver<Request>>>, manifest: Manifest) {
    // PJRT state lives and dies on this thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request until the channel closes.
            loop {
                let req = { rx.lock().unwrap().recv() };
                match req {
                    Ok(r) => {
                        let _ = r.reply.send(Err(anyhow!("PJRT client init failed: {e}")));
                    }
                    Err(_) => return,
                }
            }
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    loop {
        // Hold the receiver lock only while waiting, not while executing.
        let req = { rx.lock().unwrap().recv() };
        let req = match req {
            Ok(r) => r,
            Err(_) => return, // all senders gone: shut down
        };
        let result = run_one(&client, &mut cache, &manifest, &req);
        let _ = req.reply.send(result);
    }
}

fn run_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    req: &Request,
) -> Result<Vec<Tensor>> {
    let info = manifest.get(&req.module)?;
    if !cache.contains_key(&req.module) {
        let proto = xla::HloModuleProto::from_text_file(
            info.path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parsing {:?}: {e}", info.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", req.module))?;
        cache.insert(req.module.clone(), exe);
    }
    let exe = cache.get(&req.module).unwrap();
    let args: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(|t| {
            let lit = xla::Literal::vec1(&t.data);
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
        })
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&args)
        .map_err(|e| anyhow!("executing {}: {e}", req.module))?;
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result: {e}"))?;
    // aot.py lowers with return_tuple=True: always a tuple.
    let parts = out_lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
    if parts.len() != info.n_outputs {
        return Err(anyhow!(
            "{}: manifest says {} outputs, got {}",
            req.module,
            info.n_outputs,
            parts.len()
        ));
    }
    parts
        .into_iter()
        .map(|lit| {
            let shape = lit
                .array_shape()
                .map_err(|e| anyhow!("output shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit
                .to_vec::<f64>()
                .map_err(|e| anyhow!("output data: {e}"))?;
            Ok(Tensor::new(data, dims))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        let v = Tensor::vec(vec![5.0; 3]);
        assert_eq!(v.shape, vec![3]);
    }

    // End-to-end service tests (require built artifacts) live in
    // rust/tests/xla_backend.rs.
}
