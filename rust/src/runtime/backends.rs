//! Application backends over the PJRT runtime service: the task
//! execution functions that run their numerics through the AOT-compiled
//! Pallas/XLA artifacts instead of the native rust kernels.
//!
//! The scheduling layer is identical either way — these backends prove
//! the three layers compose: L3 routes a task, the backend marshals the
//! task's tiles/particles into `Tensor`s, the service executes the HLO
//! lowered from the Layer-1 Pallas kernel, and the results land back in
//! the shared state under the task's resource locks.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::service::{RuntimeService, Tensor};
use crate::coordinator::{KernelRegistry, TaskView};
use crate::nbody::kernels::NBodyState;
use crate::nbody::octree::{CellId, ROOT};
use crate::nbody::tasks::NbTask;
use crate::qr::driver::TileBackend;

// ----------------------------------------------------------------------
// QR
// ----------------------------------------------------------------------

/// [`TileBackend`] that dispatches every tile kernel to the AOT-compiled
/// Pallas modules (`qr_*_<b>.hlo.txt`). Only tile sizes exported by
/// `python/compile/model.py` (8, 64) are available.
pub struct XlaTileBackend {
    svc: Arc<RuntimeService>,
}

impl XlaTileBackend {
    pub fn new(svc: Arc<RuntimeService>) -> Self {
        Self { svc }
    }

    fn call(&self, name: &str, inputs: Vec<Tensor>) -> Vec<Tensor> {
        // Task functions have no error channel; a failed kernel is a
        // panic, which the executor surfaces as SchedError::WorkerPanic.
        self.svc
            .call(name, inputs)
            .unwrap_or_else(|e| panic!("XLA kernel {name} failed: {e:#}"))
    }
}

impl TileBackend for XlaTileBackend {
    fn geqrf(&self, a: &mut [f64], tau: &mut [f64], b: usize) {
        let out = self.call(
            &format!("qr_geqrf_{b}"),
            vec![Tensor::new(a.to_vec(), vec![b, b])],
        );
        a.copy_from_slice(&out[0].data);
        tau.copy_from_slice(&out[1].data);
    }

    fn larft(&self, v: &[f64], tau: &[f64], c: &mut [f64], b: usize) {
        let out = self.call(
            &format!("qr_larft_{b}"),
            vec![
                Tensor::new(v.to_vec(), vec![b, b]),
                Tensor::new(tau.to_vec(), vec![b]),
                Tensor::new(c.to_vec(), vec![b, b]),
            ],
        );
        c.copy_from_slice(&out[0].data);
    }

    fn tsqrt(&self, r: &mut [f64], a: &mut [f64], tau: &mut [f64], b: usize) {
        let out = self.call(
            &format!("qr_tsqrt_{b}"),
            vec![
                Tensor::new(r.to_vec(), vec![b, b]),
                Tensor::new(a.to_vec(), vec![b, b]),
            ],
        );
        r.copy_from_slice(&out[0].data);
        a.copy_from_slice(&out[1].data);
        tau.copy_from_slice(&out[2].data);
    }

    fn ssrft(&self, v2: &[f64], tau: &[f64], c_kj: &mut [f64], c_ij: &mut [f64], b: usize) {
        let out = self.call(
            &format!("qr_ssrft_{b}"),
            vec![
                Tensor::new(v2.to_vec(), vec![b, b]),
                Tensor::new(tau.to_vec(), vec![b]),
                Tensor::new(c_kj.to_vec(), vec![b, b]),
                Tensor::new(c_ij.to_vec(), vec![b, b]),
            ],
        );
        c_kj.copy_from_slice(&out[0].data);
        c_ij.copy_from_slice(&out[1].data);
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

// ----------------------------------------------------------------------
// N-body
// ----------------------------------------------------------------------

/// Particle buckets exported by `python/compile/model.py`.
pub const NB_BUCKETS: [usize; 2] = [128, 2048];
/// COM-list chunk length of the `nb_pc_*` modules.
pub const NB_COM_CHUNK: usize = 1024;

fn bucket_for(n: usize) -> Result<usize> {
    NB_BUCKETS
        .iter()
        .copied()
        .find(|&b| b >= n)
        .ok_or_else(|| anyhow!("cell with {n} particles exceeds the largest bucket"))
}

/// N-body task executor backed by the AOT artifacts. Mirrors the native
/// recursion exactly (touch-filtered descent); only the flat
/// leaf-vs-leaf computations go through the XLA kernels, so the covered
/// interaction set is identical to the native backend's.
pub struct XlaNbodyExec {
    svc: Arc<RuntimeService>,
}

impl XlaNbodyExec {
    pub fn new(svc: Arc<RuntimeService>) -> Self {
        Self { svc }
    }

    /// Marshal the particles of `ci` into padded tensors.
    ///
    /// # Safety
    /// Caller must hold (transitively) the lock on `ci`.
    unsafe fn gather(&self, state: &NBodyState, ci: CellId, n_pad: usize) -> (Tensor, Tensor, Tensor) {
        let c = &state.cells[ci];
        let ps = state.parts.slice(c.first, c.first + c.count);
        let mut x = vec![0.0; n_pad * 3];
        let mut m = vec![0.0; n_pad];
        let mut mask = vec![0.0; n_pad];
        for (i, p) in ps.iter().enumerate() {
            x[i * 3..i * 3 + 3].copy_from_slice(&p.x);
            m[i] = p.mass;
            mask[i] = 1.0;
        }
        (
            Tensor::new(x, vec![n_pad, 3]),
            Tensor::vec(m),
            Tensor::vec(mask),
        )
    }

    /// Add a padded acceleration tensor back onto `ci`'s particles.
    ///
    /// # Safety
    /// Caller must hold (transitively) the lock on `ci`.
    unsafe fn scatter_acc(&self, state: &NBodyState, ci: CellId, acc: &Tensor) {
        let c = &state.cells[ci];
        let ps = state.parts.slice_mut(c.first, c.first + c.count);
        for (i, p) in ps.iter_mut().enumerate() {
            for d in 0..3 {
                p.a[d] += acc.data[i * 3 + d];
            }
        }
    }

    unsafe fn self_leaf(&self, state: &NBodyState, ci: CellId) -> Result<()> {
        let n = state.cells[ci].count;
        if n < 2 {
            return Ok(());
        }
        let b = bucket_for(n)?;
        let (x, m, mask) = self.gather(state, ci, b);
        let out = self.svc.call(&format!("nb_self_{b}"), vec![x, m, mask])?;
        self.scatter_acc(state, ci, &out[0]);
        Ok(())
    }

    unsafe fn pair_leaves(&self, state: &NBodyState, ci: CellId, cj: CellId) -> Result<()> {
        let b = bucket_for(state.cells[ci].count.max(state.cells[cj].count))?;
        let (xi, mi, maski) = self.gather(state, ci, b);
        let (xj, mj, maskj) = self.gather(state, cj, b);
        let out = self
            .svc
            .call(&format!("nb_pair_{b}"), vec![xi, mi, maski, xj, mj, maskj])?;
        self.scatter_acc(state, ci, &out[0]);
        self.scatter_acc(state, cj, &out[1]);
        Ok(())
    }

    unsafe fn comp_self(&self, state: &NBodyState, ci: CellId) -> Result<()> {
        let c = &state.cells[ci];
        if let Some(pr) = c.progeny {
            for j in 0..8 {
                if state.cells[pr[j]].count == 0 {
                    continue;
                }
                self.comp_self(state, pr[j])?;
                for k in j + 1..8 {
                    if state.cells[pr[k]].count > 0 {
                        self.comp_pair(state, pr[j], pr[k])?;
                    }
                }
            }
            Ok(())
        } else {
            self.self_leaf(state, ci)
        }
    }

    unsafe fn comp_pair(&self, state: &NBodyState, ci: CellId, cj: CellId) -> Result<()> {
        use crate::nbody::octree::Cell;
        let (a, b) = (&state.cells[ci], &state.cells[cj]);
        if a.count == 0 || b.count == 0 || !Cell::touches(a, b) {
            return Ok(());
        }
        match (a.progeny, b.progeny) {
            (Some(pa), _) => {
                for ch in pa {
                    self.comp_pair(state, ch, cj)?;
                }
                Ok(())
            }
            (None, Some(pb)) => {
                for ch in pb {
                    self.comp_pair(state, ci, ch)?;
                }
                Ok(())
            }
            (None, None) => self.pair_leaves(state, ci, cj),
        }
    }

    unsafe fn comp_pc(&self, state: &NBodyState, leaf: CellId) -> Result<()> {
        let mut coms: Vec<[f64; 4]> = Vec::new();
        state.collect_pc_coms(leaf, ROOT, &mut coms);
        if coms.is_empty() {
            return Ok(());
        }
        let n = state.cells[leaf].count;
        let b = bucket_for(n)?;
        let (x, _, mask) = self.gather(state, leaf, b);
        // Chunk the COM list into the fixed kernel length, zero-mass padded.
        for chunk in coms.chunks(NB_COM_CHUNK) {
            let mut flat = vec![0.0; NB_COM_CHUNK * 4];
            for (i, c) in chunk.iter().enumerate() {
                flat[i * 4..i * 4 + 4].copy_from_slice(c);
            }
            let out = self.svc.call(
                &format!("nb_pc_{b}"),
                vec![
                    x.clone(),
                    mask.clone(),
                    Tensor::new(flat, vec![NB_COM_CHUNK, 4]),
                ],
            )?;
            self.scatter_acc(state, leaf, &out[0]);
        }
        Ok(())
    }

    /// Bind the four N-body task types to XLA-backed kernels — the same
    /// bindings as [`crate::nbody::tasks::registry`], numerics via the
    /// AOT artifacts. Kernel failures panic (tasks have no error
    /// channel) and surface as `SchedError::WorkerPanic`.
    pub fn registry<'a>(&'a self, state: &'a NBodyState) -> KernelRegistry<'a> {
        fn ok(r: Result<()>) {
            if let Err(e) = r {
                panic!("XLA N-body task failed: {e:#}");
            }
        }
        KernelRegistry::new()
            .bind(NbTask::SelfInteract, move |view: TaskView<'_>| {
                let (ci, _) = crate::nbody::tasks::decode(view.data);
                ok(unsafe { self.comp_self(state, ci) });
            })
            .bind(NbTask::PairPP, move |view: TaskView<'_>| {
                let (a, b) = crate::nbody::tasks::decode(view.data);
                ok(unsafe { self.comp_pair(state, a, b) });
            })
            .bind(NbTask::PairPC, move |view: TaskView<'_>| {
                let (ci, _) = crate::nbody::tasks::decode(view.data);
                ok(unsafe { self.comp_pc(state, ci) });
            })
            .bind(NbTask::Com, move |view: TaskView<'_>| {
                let (ci, _) = crate::nbody::tasks::decode(view.data);
                unsafe { state.compute_com(ci) };
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1).unwrap(), 128);
        assert_eq!(bucket_for(128).unwrap(), 128);
        assert_eq!(bucket_for(129).unwrap(), 2048);
        assert!(bucket_for(5000).is_err());
    }
}
