//! Lock-cheap metrics registry with Prometheus text-format 0.0.4
//! exposition.
//!
//! Hot paths hold a [`Counter`]/[`Gauge`]/[`Histogram`] *handle* — an
//! `Arc` around a cache-line-padded atomic — and bump it with one
//! relaxed RMW; the registry's mutex is taken only at registration
//! (once, at startup) and at render time (an operator scrape, seconds
//! apart). Derived values that already live elsewhere (the scheduler's
//! `QueueStats`, the shard pool's counters, the per-tenant stats table)
//! are pulled in at render time through sampling closures
//! ([`MetricsRegistry::counter_fn`]/[`gauge_fn`]) or whole-family
//! [`MetricsRegistry::collector`]s, so the existing padded atomics are
//! never duplicated or double-counted.
//!
//! The exposition is the Prometheus *text* format, version 0.0.4: for
//! every family one `# HELP`, one `# TYPE`, then one sample line per
//! label set, with histogram families expanded into cumulative
//! `_bucket{le="…"}` lines plus `_sum`/`_count`. [`parse_exposition`]
//! is the matching strict parser — the golden/round-trip tests and the
//! `repro metrics` scrape gate both use it, so an exposition the crate
//! emits is one the crate can read back.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::pad::CachePadded;

/// Metric family kind, mirroring the `# TYPE` line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    /// The kind's `# TYPE` token (`"counter"`, `"gauge"`,
    /// `"histogram"`) — comparable against [`ParsedExposition::kind_of`].
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Monotone counter handle: one padded atomic, cloned freely.
#[derive(Clone, Debug)]
pub struct Counter(Arc<CachePadded<AtomicU64>>);

impl Counter {
    fn alloc() -> Self {
        Counter(Arc::new(CachePadded::new(AtomicU64::new(0))))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge handle: a settable signed value.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<CachePadded<AtomicI64>>);

impl Gauge {
    fn alloc() -> Self {
        Gauge(Arc::new(CachePadded::new(AtomicI64::new(0))))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistogramCore {
    /// Ascending inclusive upper bounds; the implicit `+Inf` bucket is
    /// `counts[bounds.len()]`.
    bounds: Vec<u64>,
    counts: Vec<CachePadded<AtomicU64>>,
    sum: CachePadded<AtomicU64>,
}

/// Fixed-bucket histogram handle over integer-valued observations
/// (nanoseconds, bytes, widths — everything this crate measures).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    fn alloc(bounds: &[u64]) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "histogram bounds must ascend");
        let counts = (0..=bounds.len()).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        Histogram(Arc::new(HistogramCore {
            bounds: bounds.to_vec(),
            counts,
            sum: CachePadded::new(AtomicU64::new(0)),
        }))
    }

    /// Record one observation: two relaxed RMWs plus a short bound scan.
    #[inline]
    pub fn observe(&self, v: u64) {
        let c = &self.0;
        let idx = c.bounds.iter().position(|&b| v <= b).unwrap_or(c.bounds.len());
        c.counts[idx].fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.0.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    fn snapshot(&self) -> (Vec<u64>, u64) {
        let counts: Vec<u64> = self.0.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        (counts, self.0.sum.load(Ordering::Relaxed))
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
    CounterFn(Box<dyn Fn() -> u64 + Send + Sync>),
    GaugeFn(Box<dyn Fn() -> f64 + Send + Sync>),
}

struct Series {
    labels: Vec<(String, String)>,
    metric: Metric,
}

struct Family {
    name: String,
    help: String,
    kind: Kind,
    series: Vec<Series>,
}

type CollectFn = Box<dyn Fn(&mut ExpositionWriter) + Send + Sync>;

/// The registry: families registered once at startup, rendered on
/// demand. Registration mismatches (same name, different kind or help)
/// are programmer errors and panic.
#[derive(Default)]
pub struct MetricsRegistry {
    families: Mutex<Vec<Family>>,
    collectors: Mutex<Vec<CollectFn>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or re-obtain) an unlabelled counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, help, &[])
    }

    /// Register (or re-obtain) a labelled counter series.
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let m = self.register(name, help, Kind::Counter, labels, || {
            Metric::Counter(Counter::alloc())
        });
        match m {
            Metric::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    /// Register (or re-obtain) an unlabelled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    /// Register (or re-obtain) a labelled gauge series.
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let m = self.register(name, help, Kind::Gauge, labels, || Metric::Gauge(Gauge::alloc()));
        match m {
            Metric::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    /// Register (or re-obtain) a fixed-bucket histogram series.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[u64],
    ) -> Histogram {
        let m = self.register(name, help, Kind::Histogram, labels, || {
            Metric::Histogram(Histogram::alloc(bounds))
        });
        match m {
            Metric::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    /// Register a counter sampled from `f` at render time — the bridge
    /// to monotone atomics that already live elsewhere.
    pub fn counter_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.register(name, help, Kind::Counter, labels, || Metric::CounterFn(Box::new(f)));
    }

    /// Register a gauge sampled from `f` at render time.
    pub fn gauge_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register(name, help, Kind::Gauge, labels, || Metric::GaugeFn(Box::new(f)));
    }

    /// Register a whole-family render hook: called with the writer on
    /// every [`MetricsRegistry::render`], after the owned families.
    /// Used where one lock round samples many related series (the
    /// per-tenant stats table, the shard pool).
    pub fn collector(&self, f: impl Fn(&mut ExpositionWriter) + Send + Sync + 'static) {
        self.collectors.lock().unwrap().push(Box::new(f));
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        assert!(valid_metric_name(name), "invalid metric name {name:?}");
        for (k, _) in labels {
            assert!(valid_label_name(k), "invalid label name {k:?}");
        }
        let labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        let mut fams = self.families.lock().unwrap();
        let fam = match fams.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert_eq!(f.kind, kind, "metric {name} re-registered with a different kind");
                assert_eq!(f.help, help, "metric {name} re-registered with different help");
                f
            }
            None => {
                fams.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    series: Vec::new(),
                });
                fams.last_mut().unwrap()
            }
        };
        if let Some(s) = fam.series.iter().find(|s| s.labels == labels) {
            // Idempotent re-registration hands back the same handle;
            // render-time closures cannot be compared, so re-adding one
            // is refused instead of silently duplicating the series.
            match &s.metric {
                Metric::Counter(c) => return Metric::Counter(c.clone()),
                Metric::Gauge(g) => return Metric::Gauge(g.clone()),
                Metric::Histogram(h) => return Metric::Histogram(h.clone()),
                Metric::CounterFn(_) | Metric::GaugeFn(_) => {
                    panic!("sampled series {name}{labels:?} registered twice")
                }
            }
        }
        fam.series.push(Series { labels, metric: make() });
        match &fam.series.last().unwrap().metric {
            Metric::Counter(c) => Metric::Counter(c.clone()),
            Metric::Gauge(g) => Metric::Gauge(g.clone()),
            Metric::Histogram(h) => Metric::Histogram(h.clone()),
            // Render-time closures are registered, not handed back.
            Metric::CounterFn(_) => Metric::CounterFn(Box::new(|| 0)),
            Metric::GaugeFn(_) => Metric::GaugeFn(Box::new(|| 0.0)),
        }
    }

    /// Render the full exposition (owned families, then collectors).
    pub fn render(&self) -> String {
        let mut w = ExpositionWriter::new();
        {
            let fams = self.families.lock().unwrap();
            for fam in fams.iter() {
                w.family(&fam.name, fam.kind, &fam.help);
                for s in &fam.series {
                    let labels: Vec<(&str, &str)> =
                        s.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
                    match &s.metric {
                        Metric::Counter(c) => w.sample_u64(&labels, c.get()),
                        Metric::Gauge(g) => w.sample(&labels, g.get() as f64),
                        Metric::CounterFn(f) => w.sample_u64(&labels, f()),
                        Metric::GaugeFn(f) => w.sample(&labels, f()),
                        Metric::Histogram(h) => {
                            let (counts, sum) = h.snapshot();
                            w.histogram_counts(&labels, &h.0.bounds, &counts, sum);
                        }
                    }
                }
            }
        }
        let collectors = self.collectors.lock().unwrap();
        for c in collectors.iter() {
            c(&mut w);
        }
        w.finish()
    }
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn escape_label_value(v: &str, out: &mut String) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn escape_help(v: &str, out: &mut String) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn format_value(v: f64, out: &mut String) {
    if v.is_nan() {
        out.push_str("NaN");
    } else if v == f64::INFINITY {
        out.push_str("+Inf");
    } else if v == f64::NEG_INFINITY {
        out.push_str("-Inf");
    } else {
        out.push_str(&format!("{v}"));
    }
}

/// Streaming writer for the text exposition: `family()` opens a family
/// (`# HELP` + `# TYPE`), then `sample*()` append its series lines.
/// Collectors receive one of these, so sampled families render through
/// the exact same escaping and formatting as owned ones.
#[derive(Default)]
pub struct ExpositionWriter {
    out: String,
    current: Option<(String, Kind)>,
}

impl ExpositionWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a family. Panics on an invalid metric name — registration
    /// and collectors are both author-controlled.
    pub fn family(&mut self, name: &str, kind: Kind, help: &str) {
        assert!(valid_metric_name(name), "invalid metric name {name:?}");
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        escape_help(help, &mut self.out);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind.as_str());
        self.out.push('\n');
        self.current = Some((name.to_string(), kind));
    }

    /// Append one sample line for the open family.
    pub fn sample(&mut self, labels: &[(&str, &str)], value: f64) {
        self.sample_suffixed("", labels, value);
    }

    /// Append one integer sample line for the open family.
    pub fn sample_u64(&mut self, labels: &[(&str, &str)], value: u64) {
        let (name, _) = self.current.clone().expect("sample before family()");
        self.line(&name, labels, None, |out| out.push_str(&value.to_string()));
    }

    fn sample_suffixed(&mut self, suffix: &str, labels: &[(&str, &str)], value: f64) {
        let (name, _) = self.current.clone().expect("sample before family()");
        let full = format!("{name}{suffix}");
        self.line(&full, labels, None, |out| format_value(value, out));
    }

    /// Render a whole histogram series from per-bucket (non-cumulative)
    /// counts: `counts.len() == bounds.len() + 1`, the last entry being
    /// the `+Inf` overflow bucket.
    pub fn histogram_counts(
        &mut self,
        labels: &[(&str, &str)],
        bounds: &[u64],
        counts: &[u64],
        sum: u64,
    ) {
        assert_eq!(counts.len(), bounds.len() + 1, "histogram counts/bounds mismatch");
        let (name, kind) = self.current.clone().expect("sample before family()");
        assert_eq!(kind, Kind::Histogram, "histogram_counts on a non-histogram family");
        let mut cum = 0u64;
        for (i, &b) in bounds.iter().enumerate() {
            cum += counts[i];
            let le = b.to_string();
            self.line(&format!("{name}_bucket"), labels, Some(("le", &le)), |out| {
                out.push_str(&cum.to_string())
            });
        }
        cum += counts[bounds.len()];
        self.line(&format!("{name}_bucket"), labels, Some(("le", "+Inf")), |out| {
            out.push_str(&cum.to_string())
        });
        self.line(&format!("{name}_sum"), labels, None, |out| out.push_str(&sum.to_string()));
        self.line(&format!("{name}_count"), labels, None, |out| out.push_str(&cum.to_string()));
    }

    fn line(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        extra: Option<(&str, &str)>,
        write_value: impl FnOnce(&mut String),
    ) {
        self.out.push_str(name);
        let n_labels = labels.len() + usize::from(extra.is_some());
        if n_labels > 0 {
            self.out.push('{');
            let mut first = true;
            for (k, v) in labels.iter().chain(extra.iter()) {
                assert!(valid_label_name(k), "invalid label name {k:?}");
                if !first {
                    self.out.push(',');
                }
                first = false;
                self.out.push_str(k);
                self.out.push_str("=\"");
                escape_label_value(v, &mut self.out);
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        write_value(&mut self.out);
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// One parsed sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed exposition: `# TYPE` declarations plus every sample, in
/// document order.
#[derive(Clone, Debug, Default)]
pub struct ParsedExposition {
    /// `(family name, kind string)` in declaration order.
    pub types: Vec<(String, String)>,
    /// `(family name, help text)` in declaration order.
    pub helps: Vec<(String, String)>,
    pub samples: Vec<Sample>,
}

impl ParsedExposition {
    /// Declared kind of a family, if any.
    pub fn kind_of(&self, name: &str) -> Option<&str> {
        self.types.iter().find(|(n, _)| n == name).map(|(_, k)| k.as_str())
    }

    /// Value of the sample with exactly these labels (order-insensitive).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.name == name
                    && s.labels.len() == labels.len()
                    && labels
                        .iter()
                        .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
            })
            .map(|s| s.value)
    }

    /// Sum over every sample of `name`, any labels.
    pub fn sum(&self, name: &str) -> f64 {
        self.samples.iter().filter(|s| s.name == name).map(|s| s.value).sum()
    }
}

/// Strict parser for the Prometheus text format 0.0.4 subset this crate
/// emits: `# HELP`/`# TYPE` comments, sample lines with optional
/// `{label="value"}` sets (escapes `\\`, `\"`, `\n`), decimal or
/// `+Inf`/`-Inf`/`NaN` values, optional integer timestamp. Errors name
/// the offending line. Also enforces the format's grouping rule: all
/// samples of a family must be contiguous.
pub fn parse_exposition(text: &str) -> Result<ParsedExposition, String> {
    let mut out = ParsedExposition::default();
    let mut closed: Vec<String> = Vec::new();
    let mut open: Option<String> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| format!("line {}: {msg}: {line:?}", lineno + 1);
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut it = decl.splitn(2, ' ');
                let name = it.next().unwrap_or("");
                let kind = it.next().ok_or_else(|| err("TYPE missing kind"))?;
                if !valid_metric_name(name) {
                    return Err(err("invalid family name in TYPE"));
                }
                if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    return Err(err("unknown kind in TYPE"));
                }
                if out.types.iter().any(|(n, _)| n == name) {
                    return Err(err("duplicate TYPE for family"));
                }
                if closed.iter().any(|n| n == name) || open.as_deref() == Some(name) {
                    return Err(err("TYPE after the family's samples"));
                }
                out.types.push((name.to_string(), kind.to_string()));
            } else if let Some(decl) = rest.strip_prefix("HELP ") {
                let mut it = decl.splitn(2, ' ');
                let name = it.next().unwrap_or("");
                if !valid_metric_name(name) {
                    return Err(err("invalid family name in HELP"));
                }
                out.helps.push((name.to_string(), it.next().unwrap_or("").to_string()));
            }
            // Other comments are legal and ignored.
            continue;
        }
        let sample = parse_sample(line).map_err(|m| err(&m))?;
        let base = base_family(&sample.name, &out.types);
        match &open {
            Some(cur) if *cur == base => {}
            _ => {
                if closed.iter().any(|n| *n == base) {
                    return Err(err("family samples are not contiguous"));
                }
                if let Some(prev) = open.take() {
                    closed.push(prev);
                }
                open = Some(base);
            }
        }
        out.samples.push(sample);
    }
    Ok(out)
}

/// Histogram sample names (`x_bucket`, `x_sum`, `x_count`) group under
/// their declared base family `x`.
fn base_family(sample_name: &str, types: &[(String, String)]) -> String {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample_name.strip_suffix(suffix) {
            if types.iter().any(|(n, k)| n == base && k == "histogram") {
                return base.to_string();
            }
        }
    }
    sample_name.to_string()
}

fn parse_sample(line: &str) -> Result<Sample, String> {
    let bytes = line.as_bytes();
    let mut i = 0;
    while i < bytes.len() && bytes[i] != b'{' && bytes[i] != b' ' {
        i += 1;
    }
    let name = &line[..i];
    if !valid_metric_name(name) {
        return Err("invalid metric name".into());
    }
    let mut labels = Vec::new();
    if i < bytes.len() && bytes[i] == b'{' {
        i += 1;
        loop {
            while i < bytes.len() && bytes[i] == b' ' {
                i += 1;
            }
            if i < bytes.len() && bytes[i] == b'}' {
                i += 1;
                break;
            }
            let start = i;
            while i < bytes.len() && bytes[i] != b'=' {
                i += 1;
            }
            let key = &line[start..i];
            if !valid_label_name(key) {
                return Err(format!("invalid label name {key:?}"));
            }
            if i + 1 >= bytes.len() || bytes[i] != b'=' || bytes[i + 1] != b'"' {
                return Err("expected =\" after label name".into());
            }
            i += 2;
            let mut value = String::new();
            loop {
                if i >= bytes.len() {
                    return Err("unterminated label value".into());
                }
                match bytes[i] {
                    b'"' => {
                        i += 1;
                        break;
                    }
                    b'\\' => {
                        if i + 1 >= bytes.len() {
                            return Err("dangling escape".into());
                        }
                        match bytes[i + 1] {
                            b'\\' => value.push('\\'),
                            b'"' => value.push('"'),
                            b'n' => value.push('\n'),
                            c => return Err(format!("unknown escape \\{}", c as char)),
                        }
                        i += 2;
                    }
                    _ => {
                        // Advance one full UTF-8 character.
                        let s = &line[i..];
                        let c = s.chars().next().unwrap();
                        value.push(c);
                        i += c.len_utf8();
                    }
                }
            }
            labels.push((key.to_string(), value));
            if i < bytes.len() && bytes[i] == b',' {
                i += 1;
                continue;
            }
        }
    }
    let rest = line[i..].trim();
    let mut toks = rest.split_whitespace();
    let value_tok = toks.next().ok_or("missing value")?;
    let value = match value_tok {
        "+Inf" | "Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        t => t.parse::<f64>().map_err(|_| format!("bad value {t:?}"))?,
    };
    if let Some(ts) = toks.next() {
        ts.parse::<i64>().map_err(|_| format!("bad timestamp {ts:?}"))?;
    }
    if toks.next().is_some() {
        return Err("trailing tokens after sample".into());
    }
    Ok(Sample { name: name.to_string(), labels, value })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_render_and_parse() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("quicksched_test_total", "A test counter.");
        let g = reg.gauge_with("quicksched_depth", "A depth.", &[("lane", "a")]);
        c.add(3);
        g.set(-2);
        let text = reg.render();
        let parsed = parse_exposition(&text).unwrap();
        assert_eq!(parsed.kind_of("quicksched_test_total"), Some("counter"));
        assert_eq!(parsed.value("quicksched_test_total", &[]), Some(3.0));
        assert_eq!(parsed.value("quicksched_depth", &[("lane", "a")]), Some(-2.0));
    }

    #[test]
    fn re_registration_returns_the_same_handle() {
        let reg = MetricsRegistry::new();
        let a = reg.counter_with("quicksched_x_total", "X.", &[("k", "1")]);
        let b = reg.counter_with("quicksched_x_total", "X.", &[("k", "1")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        // A different label set is a distinct series in the same family.
        let c = reg.counter_with("quicksched_x_total", "X.", &[("k", "2")]);
        c.inc();
        let parsed = parse_exposition(&reg.render()).unwrap();
        assert_eq!(parsed.value("quicksched_x_total", &[("k", "1")]), Some(2.0));
        assert_eq!(parsed.value("quicksched_x_total", &[("k", "2")]), Some(1.0));
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        reg.counter("quicksched_y", "Y.");
        reg.gauge("quicksched_y", "Y.");
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("quicksched_ns", "Latency.", &[], &[10, 100, 1000]);
        for v in [5, 7, 50, 500, 5000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        let parsed = parse_exposition(&reg.render()).unwrap();
        assert_eq!(parsed.value("quicksched_ns_bucket", &[("le", "10")]), Some(2.0));
        assert_eq!(parsed.value("quicksched_ns_bucket", &[("le", "100")]), Some(3.0));
        assert_eq!(parsed.value("quicksched_ns_bucket", &[("le", "1000")]), Some(4.0));
        assert_eq!(parsed.value("quicksched_ns_bucket", &[("le", "+Inf")]), Some(5.0));
        assert_eq!(parsed.value("quicksched_ns_sum", &[]), Some(5562.0));
        assert_eq!(parsed.value("quicksched_ns_count", &[]), Some(5.0));
    }

    #[test]
    fn label_values_escape_and_roundtrip() {
        let reg = MetricsRegistry::new();
        let weird = "a\\b\"c\nd";
        reg.counter_with("quicksched_esc_total", "Escapes.", &[("path", weird)]).inc();
        let text = reg.render();
        let parsed = parse_exposition(&text).unwrap();
        assert_eq!(parsed.value("quicksched_esc_total", &[("path", weird)]), Some(1.0));
    }

    #[test]
    fn collectors_render_after_owned_families() {
        let reg = MetricsRegistry::new();
        reg.counter("quicksched_a_total", "A.").inc();
        reg.collector(|w| {
            w.family("quicksched_b", Kind::Gauge, "B.");
            w.sample(&[("src", "collector")], 7.5);
        });
        let parsed = parse_exposition(&reg.render()).unwrap();
        assert_eq!(parsed.value("quicksched_b", &[("src", "collector")]), Some(7.5));
    }

    #[test]
    fn sampled_series_read_external_atomics() {
        use std::sync::atomic::AtomicU64;
        let reg = MetricsRegistry::new();
        let ext = Arc::new(AtomicU64::new(41));
        let e2 = Arc::clone(&ext);
        reg.counter_fn("quicksched_ext_total", "External.", &[], move || {
            e2.load(Ordering::Relaxed)
        });
        ext.fetch_add(1, Ordering::Relaxed);
        let parsed = parse_exposition(&reg.render()).unwrap();
        assert_eq!(parsed.value("quicksched_ext_total", &[]), Some(42.0));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_exposition("1bad_name 3\n").is_err());
        assert!(parse_exposition("x{l=\"unterminated} 3\n").is_err());
        assert!(parse_exposition("x 3 4 5\n").is_err());
        assert!(parse_exposition("x notanumber\n").is_err());
        // Non-contiguous family samples violate the grouping rule.
        assert!(parse_exposition("a 1\nb 2\na 3\n").is_err());
        // TYPE after samples of the family.
        assert!(parse_exposition("a 1\n# TYPE a counter\n").is_err());
    }
}
