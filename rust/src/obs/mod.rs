//! Observability: unified metrics and exportable timelines.
//!
//! The paper's evaluation *is* observability — Figs 9/12 are per-core
//! Gantt charts and Fig 13 is accumulated cost per task type plus
//! `qsched_gettask` overhead. This module makes those signals (and the
//! service-level ones the server grew on top) first-class and cheap
//! enough to leave on:
//!
//! - [`registry`] — [`MetricsRegistry`]: counters, gauges and
//!   fixed-bucket histograms behind padded-atomic handles, rendered as
//!   Prometheus text-format 0.0.4 ([`MetricsRegistry::render`]) and
//!   parsed back by [`parse_exposition`] (the scrape gate).
//! - [`trace`] — [`TraceSink`]: `TimelineRecord`s and job lifecycle
//!   phases serialized as Chrome `trace_event` JSON, loadable in
//!   `chrome://tracing`/Perfetto; [`validate_chrome_trace`] checks the
//!   schema and per-lane span exclusivity.
//!
//! Consumers: the scheduler's always-on `gettask` counters
//! (`Scheduler::obs_counters`), the server's registry wired up in
//! `SchedServer::start` (`SchedServer::metrics_text`), the wire
//! listener's per-connection frame/byte/error counters, the `Metrics`
//! wire request behind `RemoteClient::metrics_text`, and the CLI's
//! `repro trace` / `repro metrics` / `repro serve --metrics`.

pub mod registry;
pub mod trace;

pub use registry::{
    parse_exposition, Counter, ExpositionWriter, Gauge, Histogram, Kind, MetricsRegistry,
    ParsedExposition, Sample,
};
pub use trace::{validate_chrome_trace, TraceSink};
