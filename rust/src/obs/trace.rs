//! Chrome `trace_event` timelines: the paper's Fig 9/12 Gantt view,
//! exportable from any run.
//!
//! [`TraceSink`] collects spans — per-task [`TimelineRecord`]s from a
//! run's [`RunMetrics`], or per-job lifecycle phases reconstructed from
//! a `JobReport`'s queue/setup/service breakdown — and serializes them
//! as the Trace Event JSON format (`"X"` complete events plus `"M"`
//! metadata events naming processes and worker lanes). The output
//! loads directly in `chrome://tracing` and Perfetto.
//!
//! Timestamps are microseconds (the format's unit); the crate records
//! nanoseconds, so every span is emitted with fractional-µs precision
//! (`ns / 1000` with three decimals — exact at ns resolution).
//!
//! [`validate_chrome_trace`] is the matching schema checker used by the
//! tier-1 trace tests: it parses the JSON (a small total parser — no
//! serde offline), verifies every event carries the required fields,
//! and asserts per-`(pid, tid)` complete-event spans do not overlap —
//! a worker lane executes one task at a time, and so must its Gantt row.

use std::io;
use std::path::Path;

use crate::coordinator::RunMetrics;

#[derive(Clone, Debug)]
enum Arg {
    Str(String),
    U64(u64),
    Bool(bool),
}

#[derive(Clone, Debug)]
struct TraceEvent {
    name: String,
    cat: &'static str,
    ph: char,
    ts_us: f64,
    dur_us: Option<f64>,
    pid: u32,
    tid: u32,
    args: Vec<(&'static str, Arg)>,
}

/// Collects trace events and renders them as Chrome `trace_event` JSON.
#[derive(Default)]
pub struct TraceSink {
    events: Vec<TraceEvent>,
}

fn us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Name a process row (`"M"` metadata event).
    pub fn name_process(&mut self, pid: u32, name: &str) {
        self.metadata("process_name", pid, 0, name);
    }

    /// Name a thread (worker) lane within a process.
    pub fn name_thread(&mut self, pid: u32, tid: u32, name: &str) {
        self.metadata("thread_name", pid, tid, name);
    }

    fn metadata(&mut self, kind: &str, pid: u32, tid: u32, name: &str) {
        self.events.push(TraceEvent {
            name: kind.to_string(),
            cat: "__metadata",
            ph: 'M',
            ts_us: 0.0,
            dur_us: None,
            pid,
            tid,
            args: vec![("name", Arg::Str(name.to_string()))],
        });
    }

    /// Append one complete (`"X"`) span on lane `(pid, tid)`.
    pub fn add_span(&mut self, name: &str, pid: u32, tid: u32, start_ns: u64, dur_ns: u64) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat: "span",
            ph: 'X',
            ts_us: us(start_ns),
            dur_us: Some(us(dur_ns)),
            pid,
            tid,
            args: Vec::new(),
        });
    }

    /// Convert a whole run's timeline (one span per executed task, one
    /// lane per worker) into the sink. Requires the run to have been
    /// recorded with `SchedConfig::with_timeline(true)`; a timeline-less
    /// run contributes only the process/worker metadata.
    pub fn add_run(&mut self, m: &RunMetrics, pid: u32) {
        self.add_run_named(m, pid, |ty| format!("type{ty}"));
    }

    /// [`TraceSink::add_run`] with task-type names supplied by the
    /// caller (e.g. the QR driver's DGEQRF/DLARFT/DTSQRF/DSSRFT).
    pub fn add_run_named(&mut self, m: &RunMetrics, pid: u32, name_of: impl Fn(u32) -> String) {
        self.name_process(pid, "quicksched run");
        for w in 0..m.workers.max(1) {
            self.name_thread(pid, w as u32, &format!("worker {w}"));
        }
        for r in &m.timeline {
            self.events.push(TraceEvent {
                name: name_of(r.type_id),
                cat: "task",
                ph: 'X',
                ts_us: us(r.start_ns),
                dur_us: Some(us(r.duration_ns())),
                pid,
                tid: r.worker,
                args: vec![
                    ("task", Arg::U64(r.tid.0 as u64)),
                    ("stolen", Arg::Bool(r.stolen)),
                    ("gettask_ns", Arg::U64(r.get_ns)),
                ],
            });
        }
    }

    /// Reconstruct a job's lifecycle (queued → setup → service phases,
    /// back-to-back and ending at `end_ns`) as three spans on lane
    /// `(pid, lane)` — the server-side Gantt row a `JobReport`'s
    /// breakdown describes.
    pub fn add_job(
        &mut self,
        job: u64,
        pid: u32,
        lane: u32,
        end_ns: u64,
        queue_ns: u64,
        setup_ns: u64,
        service_ns: u64,
    ) {
        let total = queue_ns + setup_ns + service_ns;
        let start = end_ns.saturating_sub(total);
        let phases = [("queued", queue_ns), ("setup", setup_ns), ("service", service_ns)];
        let mut t = start;
        for (phase, dur) in phases {
            if dur > 0 {
                self.events.push(TraceEvent {
                    name: format!("job{job}:{phase}"),
                    cat: "job",
                    ph: 'X',
                    ts_us: us(t),
                    dur_us: Some(us(dur)),
                    pid,
                    tid: lane,
                    args: vec![("job", Arg::U64(job))],
                });
            }
            t += dur;
        }
    }

    /// Render the Trace Event JSON document (object form, so Perfetto
    /// and `chrome://tracing` both load it).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"name\":");
            json_string(&e.name, &mut out);
            out.push_str(",\"cat\":");
            json_string(e.cat, &mut out);
            out.push_str(&format!(
                ",\"ph\":\"{}\",\"ts\":{:.3},\"pid\":{},\"tid\":{}",
                e.ph, e.ts_us, e.pid, e.tid
            ));
            if let Some(d) = e.dur_us {
                out.push_str(&format!(",\"dur\":{d:.3}"));
            }
            if !e.args.is_empty() {
                out.push_str(",\"args\":{");
                for (j, (k, v)) in e.args.iter().enumerate() {
                    if j > 0 {
                        out.push(',');
                    }
                    json_string(k, &mut out);
                    out.push(':');
                    match v {
                        Arg::Str(s) => json_string(s, &mut out),
                        Arg::U64(n) => out.push_str(&n.to_string()),
                        Arg::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
                    }
                }
                out.push('}');
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Write the JSON document to `path`.
    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------
// Schema validation (test + CI gate side).

#[derive(Clone, Debug, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonParser<'a> {
    fn new(s: &'a str) -> Self {
        Self { b: s.as_bytes(), i: 0 }
    }

    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("json byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.skip_ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        self.skip_ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("json byte {start}: bad number {s:?}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            if self.i >= self.b.len() {
                return self.err("unterminated string");
            }
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = *self.b.get(self.i).ok_or("dangling escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("short \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                _ => {
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            kv.push((key, self.value()?));
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = JsonParser::new(text);
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("json byte {}: trailing data", p.i));
    }
    Ok(v)
}

/// Validate a Chrome `trace_event` document: parses the JSON, accepts
/// either the bare-array or the `{"traceEvents": […]}` object form,
/// checks every event is an object with `ph`/`pid`/`tid` (and
/// `name`/`ts`/`dur` for `"X"` complete events), and verifies complete
/// events on one `(pid, tid)` lane never overlap (1 ns tolerance for
/// the µs float conversion). Returns the event count.
pub fn validate_chrome_trace(text: &str) -> Result<usize, String> {
    let doc = parse_json(text)?;
    let events = match &doc {
        Json::Arr(items) => items,
        Json::Obj(_) => match doc.get("traceEvents") {
            Some(Json::Arr(items)) => items,
            _ => return Err("traceEvents missing or not an array".into()),
        },
        _ => return Err("top level is neither array nor object".into()),
    };
    let mut lanes: Vec<((f64, f64), Vec<(f64, f64)>)> = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        if !matches!(ev, Json::Obj(_)) {
            return Err(format!("event {i} is not an object"));
        }
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let pid = ev
            .get("pid")
            .and_then(Json::as_num)
            .ok_or_else(|| format!("event {i}: missing pid"))?;
        let tid = ev
            .get("tid")
            .and_then(Json::as_num)
            .ok_or_else(|| format!("event {i}: missing tid"))?;
        if ph == "X" {
            ev.get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("event {i}: X without name"))?;
            let ts = ev
                .get("ts")
                .and_then(Json::as_num)
                .ok_or_else(|| format!("event {i}: X without ts"))?;
            let dur = ev
                .get("dur")
                .and_then(Json::as_num)
                .ok_or_else(|| format!("event {i}: X without dur"))?;
            let key = (pid, tid);
            match lanes.iter_mut().find(|(k, _)| *k == key) {
                Some((_, spans)) => spans.push((ts, dur)),
                None => lanes.push((key, vec![(ts, dur)])),
            }
        }
    }
    for ((pid, tid), spans) in lanes.iter_mut() {
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in spans.windows(2) {
            let (t0, d0) = w[0];
            let (t1, _) = w[1];
            // 1 ns in µs — tolerance for the fractional-µs conversion.
            if t0 + d0 > t1 + 0.001 {
                return Err(format!(
                    "lane pid={pid} tid={tid}: spans overlap ({t0}+{d0} > {t1})"
                ));
            }
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_and_metadata_validate() {
        let mut sink = TraceSink::new();
        sink.name_process(1, "proc");
        sink.name_thread(1, 0, "worker 0");
        sink.add_span("a", 1, 0, 0, 1_000);
        sink.add_span("b", 1, 0, 1_000, 2_500);
        sink.add_span("c", 1, 1, 500, 10_000);
        let json = sink.to_json();
        assert_eq!(validate_chrome_trace(&json).unwrap(), 5);
    }

    #[test]
    fn overlapping_spans_on_one_lane_fail() {
        let mut sink = TraceSink::new();
        sink.add_span("a", 0, 0, 0, 2_000);
        sink.add_span("b", 0, 0, 1_000, 2_000);
        let err = validate_chrome_trace(&sink.to_json()).unwrap_err();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn job_lifecycle_spans_are_contiguous() {
        let mut sink = TraceSink::new();
        sink.add_job(7, 0, 3, 10_000, 2_000, 1_000, 4_000);
        let json = sink.to_json();
        assert_eq!(validate_chrome_trace(&json).unwrap(), 3);
        assert!(json.contains("job7:queued"));
        assert!(json.contains("job7:service"));
    }

    #[test]
    fn names_escape_into_valid_json() {
        let mut sink = TraceSink::new();
        sink.add_span("we\"ird\\name\n", 0, 0, 0, 10);
        assert_eq!(validate_chrome_trace(&sink.to_json()).unwrap(), 1);
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\": 3}").is_err());
        assert!(validate_chrome_trace("[{\"ph\":\"X\",\"pid\":0,\"tid\":0}]").is_err());
        assert!(validate_chrome_trace("[1,2]").is_err());
    }
}
