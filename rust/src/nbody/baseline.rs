//! Gadget-2 stand-in: a *traditional* Barnes-Hut implementation — one
//! tree walk per particle with a geometric opening criterion, statically
//! domain-decomposed across ranks with bulk-synchronous steps (see
//! DESIGN.md §Hardware-substitutions).
//!
//! Differences from the task-based solver that this baseline preserves
//! (they are what Fig. 11 measures):
//! * per-particle pointer-chasing walks instead of per-leaf walks over
//!   contiguous particles → worse cache behaviour (modelled as a
//!   per-interaction cost penalty calibrated from the paper's measured
//!   1.9× single-core gap);
//! * static equal-count domain decomposition instead of dynamic
//!   work-stealing → load imbalance;
//! * bulk-synchronous steps → stragglers dominate.

use super::kernels::{interact_com, EPS2};
use super::octree::{Cell, CellId, Octree, ROOT};
use super::part::Part;

/// Opening criterion: open a node when `h / d > theta` (Gadget's
/// classic Barnes-Hut criterion; the paper uses θ = 0.5).
pub struct TreeWalker<'t> {
    pub tree: &'t Octree,
    pub coms: Vec<[f64; 4]>,
    pub theta: f64,
}

impl<'t> TreeWalker<'t> {
    pub fn new(tree: &'t Octree, theta: f64) -> Self {
        // Bottom-up COM pass (children after parents in the arena).
        let mut coms = vec![[0.0f64; 4]; tree.cells.len()];
        for ci in (0..tree.cells.len()).rev() {
            let c = &tree.cells[ci];
            let mut acc = [0.0f64; 4];
            if let Some(pr) = c.progeny {
                for ch in pr {
                    let com = coms[ch];
                    acc[3] += com[3];
                    for d in 0..3 {
                        acc[d] += com[d] * com[3];
                    }
                }
            } else {
                for p in &tree.parts[c.first..c.first + c.count] {
                    acc[3] += p.mass;
                    for d in 0..3 {
                        acc[d] += p.x[d] * p.mass;
                    }
                }
            }
            if acc[3] > 0.0 {
                for d in 0..3 {
                    acc[d] /= acc[3];
                }
            }
            coms[ci] = acc;
        }
        Self { tree, coms, theta }
    }

    /// Walk the tree for one particle, accumulating acceleration into
    /// `p.a` and returning the number of interactions performed (the
    /// per-particle work measure used by the decomposition model).
    pub fn walk(&self, p: &mut Part) -> usize {
        self.walk_node(p, ROOT)
    }

    fn walk_node(&self, p: &mut Part, node: CellId) -> usize {
        let c: &Cell = &self.tree.cells[node];
        if c.count == 0 {
            return 0;
        }
        let com = self.coms[node];
        let dx = [com[0] - p.x[0], com[1] - p.x[1], com[2] - p.x[2]];
        let d2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2];
        let open = c.h * c.h > self.theta * self.theta * d2;
        if !open && !Self::contains(c, p) {
            interact_com(p, &[com[0], com[1], com[2]], com[3]);
            return 1;
        }
        if let Some(pr) = c.progeny {
            pr.iter().map(|&ch| self.walk_node(p, ch)).sum()
        } else {
            // Leaf: direct interactions (skipping self).
            let mut n = 0;
            for q in &self.tree.parts[c.first..c.first + c.count] {
                if q.id == p.id {
                    continue;
                }
                let dx = [q.x[0] - p.x[0], q.x[1] - p.x[1], q.x[2] - p.x[2]];
                let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
                let inv_r = 1.0 / r2.sqrt();
                let w = q.mass * inv_r * inv_r * inv_r;
                for d in 0..3 {
                    p.a[d] += w * dx[d];
                }
                n += 1;
            }
            n
        }
    }

    fn contains(c: &Cell, p: &Part) -> bool {
        (0..3).all(|d| p.x[d] >= c.loc[d] && p.x[d] < c.loc[d] + c.h)
    }

    /// Full serial solve: walk every particle; returns (particles with
    /// accelerations, per-particle interaction counts).
    pub fn solve(&self) -> (Vec<Part>, Vec<usize>) {
        let mut out = self.tree.parts.clone();
        let mut work = Vec::with_capacity(out.len());
        for p in out.iter_mut() {
            p.a = [0.0; 3];
            work.push(self.walk(p));
        }
        (out, work)
    }
}

/// Bulk-synchronous static-decomposition time model for the Fig. 11
/// comparator. Particles are split into `ranks` contiguous equal-count
/// domains (Gadget's space-filling-curve decomposition over an already
/// hierarchically sorted array is approximately this); each rank walks
/// its particles; a step ends when the slowest rank finishes, plus a
/// per-step communication/tree-exchange term that grows with ranks.
///
/// `ns_per_interaction` is calibrated so that the single-rank time
/// matches the measured serial walk; `comm_ns(ranks)` models the MPI
/// overhead (α·N^(2/3)·ranks^(1/3) ghost-exchange scaling).
pub fn bsp_times(work: &[usize], ranks: usize, ns_per_interaction: f64, comm_alpha: f64) -> u64 {
    assert!(ranks > 0);
    let n = work.len();
    let per = n.div_ceil(ranks);
    let mut max_domain = 0.0f64;
    for r in 0..ranks {
        let lo = r * per;
        let hi = ((r + 1) * per).min(n);
        if lo >= hi {
            continue;
        }
        let w: f64 = work[lo..hi].iter().map(|&x| x as f64).sum();
        max_domain = max_domain.max(w);
    }
    let compute = max_domain * ns_per_interaction;
    let comm = if ranks > 1 {
        comm_alpha * (n as f64).powf(2.0 / 3.0) * (ranks as f64).powf(1.0 / 3.0)
    } else {
        0.0
    };
    (compute + comm) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbody::direct::{direct_sum, rms_rel_error};
    use crate::nbody::part::uniform_cloud;

    #[test]
    fn walk_matches_direct_for_tiny_theta() {
        // θ → 0 never approximates: must equal the direct sum exactly.
        let cloud = uniform_cloud(400, 31);
        let tree = Octree::build(cloud.clone(), 32);
        let walker = TreeWalker::new(&tree, 1e-9);
        let (got, _) = walker.solve();
        let want = direct_sum(&cloud);
        let rel = rms_rel_error(&got, &want);
        assert!(rel < 1e-12, "θ→0 walk must be exact, got {rel}");
    }

    #[test]
    fn walk_accuracy_at_half_theta() {
        let cloud = uniform_cloud(2000, 32);
        let tree = Octree::build(cloud.clone(), 64);
        let walker = TreeWalker::new(&tree, 0.5);
        let (got, work) = walker.solve();
        let want = direct_sum(&cloud);
        let rel = rms_rel_error(&got, &want);
        assert!(rel < 0.02, "θ=0.5 error {rel}");
        // and it must be cheaper than direct summation (N(N-1) directed
        // interactions); at N=2000 the tree already saves >60%.
        let total: usize = work.iter().sum();
        assert!(total < 2000 * 1999 * 4 / 10, "walk did {total} interactions");
    }

    #[test]
    fn theta_tradeoff_monotone() {
        let cloud = uniform_cloud(1500, 33);
        let tree = Octree::build(cloud.clone(), 64);
        let want = direct_sum(&cloud);
        let mut last_work = usize::MAX;
        for theta in [0.3, 0.6, 0.9] {
            let walker = TreeWalker::new(&tree, theta);
            let (got, work) = walker.solve();
            let total: usize = work.iter().sum();
            assert!(total < last_work, "larger θ must do less work");
            last_work = total;
            let rel = rms_rel_error(&got, &want);
            assert!(rel < 0.05, "θ={theta} error {rel}");
        }
    }

    #[test]
    fn bsp_single_rank_is_serial_work() {
        let work = vec![10usize; 100];
        let t1 = bsp_times(&work, 1, 2.0, 1000.0);
        assert_eq!(t1, 2000);
    }

    #[test]
    fn bsp_imbalance_and_comm_hurt() {
        // Skewed work: first half heavy.
        let mut work = vec![1usize; 1000];
        for w in work.iter_mut().take(500) {
            *w = 9;
        }
        let t1 = bsp_times(&work, 1, 1.0, 50.0);
        let t2 = bsp_times(&work, 2, 1.0, 50.0);
        // Perfect split would be t1/2 = 2500; static split gives 4500+comm.
        assert!(t2 > t1 / 2, "imbalance must show: {t2} vs {}", t1 / 2);
        let t2_nocomm = bsp_times(&work, 2, 1.0, 0.0);
        assert!(t2 > t2_nocomm);
    }

    #[test]
    fn bsp_more_ranks_never_slower_compute() {
        let work: Vec<usize> = (0..1024).map(|i| 1 + i % 7).collect();
        let t8 = bsp_times(&work, 8, 1.0, 0.0);
        let t64 = bsp_times(&work, 64, 1.0, 0.0);
        assert!(t64 <= t8);
    }
}
