//! O(N²) direct-summation oracle for the Barnes-Hut verification
//! (identical force law and softening as the tree kernels).

use super::kernels::EPS2;
use super::part::Part;

/// Direct sum over all pairs; returns particles ordered by `id` with
/// accelerations filled in (input order irrelevant).
pub fn direct_sum(parts: &[Part]) -> Vec<Part> {
    let mut out: Vec<Part> = parts.to_vec();
    out.sort_unstable_by_key(|p| p.id);
    for p in out.iter_mut() {
        p.a = [0.0; 3];
    }
    for i in 0..out.len() {
        let (head, tail) = out.split_at_mut(i + 1);
        let pi = &mut head[i];
        for pj in tail.iter_mut() {
            let dx = [
                pj.x[0] - pi.x[0],
                pj.x[1] - pi.x[1],
                pj.x[2] - pi.x[2],
            ];
            let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
            let inv_r = 1.0 / r2.sqrt();
            let inv_r3 = inv_r * inv_r * inv_r;
            for d in 0..3 {
                pi.a[d] += pj.mass * inv_r3 * dx[d];
                pj.a[d] -= pi.mass * inv_r3 * dx[d];
            }
        }
    }
    out
}

/// RMS relative error of accelerations `got` vs the oracle `want`
/// (both keyed by particle id).
pub fn rms_rel_error(got: &[Part], want: &[Part]) -> f64 {
    assert_eq!(got.len(), want.len());
    let mut by_id: Vec<&Part> = want.iter().collect();
    by_id.sort_unstable_by_key(|p| p.id);
    let mut num = 0.0;
    let mut den = 0.0;
    for g in got {
        let w = by_id[g.id as usize];
        for d in 0..3 {
            num += (g.a[d] - w.a[d]).powi(2);
            den += w.a[d].powi(2);
        }
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbody::part::uniform_cloud;

    #[test]
    fn two_body() {
        let parts = vec![
            Part::at([0.0, 0.0, 0.0], 1.0, 0),
            Part::at([2.0, 0.0, 0.0], 4.0, 1),
        ];
        let out = direct_sum(&parts);
        assert!((out[0].a[0] - 1.0).abs() < 1e-9); // 4/4
        assert!((out[1].a[0] + 0.25).abs() < 1e-9); // -1/4
    }

    #[test]
    fn momentum_conserved() {
        let parts = uniform_cloud(200, 5);
        let out = direct_sum(&parts);
        let mut p = [0.0; 3];
        for q in &out {
            for d in 0..3 {
                p[d] += q.mass * q.a[d];
            }
        }
        for d in 0..3 {
            assert!(p[d].abs() < 1e-12, "net force {p:?}");
        }
    }

    #[test]
    fn order_independent() {
        let parts = uniform_cloud(50, 6);
        let mut rev = parts.clone();
        rev.reverse();
        let a = direct_sum(&parts);
        let b = direct_sum(&rev);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            for d in 0..3 {
                assert!((x.a[d] - y.a[d]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rms_error_zero_on_self() {
        let parts = uniform_cloud(30, 7);
        let out = direct_sum(&parts);
        assert_eq!(rms_rel_error(&out, &out), 0.0);
    }
}
