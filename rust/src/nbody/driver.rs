//! End-to-end Barnes-Hut driver: tree build → task graph → run
//! (threaded or virtual-time), plus the cost model used for the
//! Fig. 11/12/13 simulations.

use crate::coordinator::{
    ContentionCost, CostModel, RunMetrics, SchedConfig, Scheduler, SimCtx, TaskView,
};

use super::kernels::NBodyState;
use super::octree::Octree;
use super::part::Part;
use super::tasks::{build_tasks, registry, NbGraph};

/// Outcome of a Barnes-Hut run.
pub struct NbRun {
    pub metrics: RunMetrics,
    pub graph: NbGraph,
}

/// Build the tree and solve on real threads; returns the particles with
/// accelerations plus run metrics.
pub fn run_threaded(
    parts: Vec<Part>,
    n_max: usize,
    n_task: usize,
    config: SchedConfig,
    nr_threads: usize,
) -> crate::coordinator::Result<(Vec<Part>, NbRun)> {
    let tree = Octree::build(parts, n_max);
    let state = NBodyState::from_tree(tree);
    let mut sched = Scheduler::new(config)?;
    let graph = build_tasks(&mut sched, &state, n_task);
    sched.prepare()?;
    let metrics = sched.run_registry(nr_threads, &registry(&state))?;
    Ok((state.into_parts(), NbRun { metrics, graph }))
}

/// Cost model for the Barnes-Hut simulation. Task costs are interaction
/// counts (`count²`, `ni·nj`, walk-scaled `count`); `ns_per_unit` is the
/// calibrated time per interaction. The memory-bandwidth contention of
/// the Opteron's shared L2 (Fig. 13: pair types +30–40% past 32 cores,
/// particle–cell only +10%) is modelled by [`ContentionCost`] with
/// per-type sensitivities `[self, pp, pc, com]`.
pub fn nb_cost_model(ns_per_unit: f64) -> ContentionCost<NbScale> {
    ContentionCost {
        base: NbScale { ns_per_unit },
        // §4.2/Fig 13: pair-interaction types are memory-bound (+30-40%),
        // the compute-dense particle-cell walks only +10%.
        sensitivity: vec![0.35, 0.40, 0.10, 0.10],
        // Opteron 6376: 32 two-core modules sharing L2.
        machine_modules: 32,
    }
}

/// Plain linear scaling of interaction-count costs.
pub struct NbScale {
    pub ns_per_unit: f64,
}

impl CostModel for NbScale {
    fn duration_ns(&self, view: TaskView<'_>, _ctx: &SimCtx) -> u64 {
        ((view.cost.max(1) as f64) * self.ns_per_unit).max(1.0) as u64
    }
}

/// Schedule the Barnes-Hut task graph for `parts` on `cores` virtual
/// cores (no numerics — durations from `model`).
pub fn run_sim<M: CostModel>(
    parts: Vec<Part>,
    n_max: usize,
    n_task: usize,
    config: SchedConfig,
    cores: usize,
    model: &M,
) -> crate::coordinator::Result<NbRun> {
    let tree = Octree::build(parts, n_max);
    let state = NBodyState::from_tree(tree);
    let mut sched = Scheduler::new(config)?;
    let graph = build_tasks(&mut sched, &state, n_task);
    sched.prepare()?;
    let metrics = sched.run_sim(cores, model)?;
    Ok(NbRun { metrics, graph })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbody::direct::{direct_sum, rms_rel_error};
    use crate::nbody::part::{plummer_cloud, uniform_cloud};

    #[test]
    fn threaded_solve_accurate() {
        let cloud = uniform_cloud(2500, 51);
        let (got, run) =
            run_threaded(cloud.clone(), 64, 300, SchedConfig::new(2), 2).unwrap();
        let want = direct_sum(&cloud);
        let rel = rms_rel_error(&got, &want);
        assert!(rel < 0.02, "force error {rel}");
        assert!(run.metrics.tasks_run > 10);
    }

    #[test]
    fn plummer_cloud_solves() {
        // Non-uniform tree exercises the unbalanced recursion paths.
        let cloud = plummer_cloud(2500, 52);
        let (got, _) = run_threaded(cloud.clone(), 32, 200, SchedConfig::new(4), 4).unwrap();
        let want = direct_sum(&cloud);
        let rel = rms_rel_error(&got, &want);
        assert!(rel < 0.03, "plummer force error {rel}");
    }

    #[test]
    fn sim_scales() {
        let t = |cores: usize| {
            run_sim(
                uniform_cloud(20_000, 53),
                100,
                800,
                SchedConfig::new(cores),
                cores,
                &NbScale { ns_per_unit: 5.0 },
            )
            .unwrap()
            .metrics
            .elapsed_ns
        };
        let t1 = t(1);
        let t8 = t(8);
        let speedup = t1 as f64 / t8 as f64;
        assert!(speedup > 4.0, "BH sim speedup {speedup}");
    }

    #[test]
    fn contention_model_shows_fig13_knee() {
        // With the contention model, 64-core efficiency must drop below
        // 32-core efficiency scaled — the Fig 11/13 plateau.
        let run = |cores: usize| {
            run_sim(
                uniform_cloud(20_000, 54),
                100,
                800,
                SchedConfig::new(cores),
                cores,
                &nb_cost_model(5.0),
            )
            .unwrap()
            .metrics
        };
        let m1 = run(1);
        let m32 = run(32);
        let m64 = run(64);
        let eff32 = m32.parallel_efficiency(m1.elapsed_ns);
        let eff64 = m64.parallel_efficiency(m1.elapsed_ns);
        assert!(
            eff64 < eff32,
            "contention must flatten scaling: eff32={eff32:.2} eff64={eff64:.2}"
        );
    }
}
