//! Particle data (paper Appendix C `struct part`).

/// One particle: position, accumulated acceleration, mass, id.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Part {
    pub x: [f64; 3],
    pub a: [f64; 3],
    pub mass: f64,
    pub id: u32,
}

impl Part {
    pub fn at(x: [f64; 3], mass: f64, id: u32) -> Self {
        Self { x, a: [0.0; 3], mass, id }
    }
}

/// Generate `n` particles with iid uniform coordinates in `[0,1)³` and
/// unit mass / n (paper §4.2: "1 000 000 particles with uniformly random
/// coordinates in [0,1]³").
pub fn uniform_cloud(n: usize, seed: u64) -> Vec<Part> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|i| {
            Part::at(
                [rng.f64(), rng.f64(), rng.f64()],
                1.0 / n as f64,
                i as u32,
            )
        })
        .collect()
}

/// A centrally-concentrated Plummer-like cloud (used by the examples to
/// exercise non-uniform trees).
pub fn plummer_cloud(n: usize, seed: u64) -> Vec<Part> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|i| {
            // Radius from the Plummer cumulative mass profile, clamped
            // into the unit box around (0.5, 0.5, 0.5).
            let m: f64 = rng.f64().max(1e-9);
            let r = 0.1 / (m.powf(-2.0 / 3.0) - 1.0).max(1e-9).sqrt();
            let r = r.min(0.45);
            // Random direction.
            let z = rng.range_f64(-1.0, 1.0);
            let phi = rng.range_f64(0.0, 2.0 * std::f64::consts::PI);
            let s = (1.0 - z * z).sqrt();
            Part::at(
                [
                    0.5 + r * s * phi.cos(),
                    0.5 + r * s * phi.sin(),
                    0.5 + r * z,
                ],
                1.0 / n as f64,
                i as u32,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_unit_box() {
        let ps = uniform_cloud(1000, 1);
        assert_eq!(ps.len(), 1000);
        for p in &ps {
            for d in 0..3 {
                assert!((0.0..1.0).contains(&p.x[d]));
            }
            assert!(p.a == [0.0; 3]);
            assert!((p.mass - 1e-3).abs() < 1e-15);
        }
        // ids are the original order
        assert_eq!(ps[7].id, 7);
    }

    #[test]
    fn uniform_deterministic() {
        assert_eq!(uniform_cloud(64, 9), uniform_cloud(64, 9));
        assert_ne!(uniform_cloud(64, 9), uniform_cloud(64, 10));
    }

    #[test]
    fn plummer_in_unit_box() {
        let ps = plummer_cloud(2000, 3);
        for p in &ps {
            for d in 0..3 {
                assert!((0.0..=1.0).contains(&p.x[d]), "{:?}", p.x);
            }
        }
        // Concentrated: more than half within r < 0.2 of the center.
        let close = ps
            .iter()
            .filter(|p| {
                let dx = [p.x[0] - 0.5, p.x[1] - 0.5, p.x[2] - 0.5];
                (dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2]).sqrt() < 0.2
            })
            .count();
        assert!(close > 1000, "only {close} particles near center");
    }
}
