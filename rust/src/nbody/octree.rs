//! Octree with hierarchical contiguous particle storage (paper §4.2,
//! Fig. 10): every cell, at every level, addresses its particles as a
//! contiguous range `[first, first+count)` of the single global `parts`
//! array. Sorting into this layout is a recursive 8-way partition
//! (QuickSort-like, O(N log N)).
//!
//! Cells carry integer coordinates `(level, ix, iy, iz)` so adjacency
//! ("are two boxes touching?") is exact integer arithmetic — the
//! criterion both the pair tasks and the particle–cell tree-walk use.

use super::part::Part;

/// Index of a cell in the arena.
pub type CellId = usize;

/// One octree cell (paper Appendix C `struct cell`, minus the task/res
/// handles which live in the task-graph builder).
#[derive(Clone, Debug)]
pub struct Cell {
    /// Geometric anchor (lower corner) and edge length.
    pub loc: [f64; 3],
    pub h: f64,
    /// Refinement level (root = 0) and integer coords at that level.
    pub level: u32,
    pub ix: [u32; 3],
    /// First particle and particle count in the global array.
    pub first: usize,
    pub count: usize,
    /// Child cells (all 8 or none).
    pub progeny: Option<[CellId; 8]>,
    /// Hierarchical parent (root: None).
    pub parent: Option<CellId>,
}

impl Cell {
    pub fn is_split(&self) -> bool {
        self.progeny.is_some()
    }

    /// Do the boxes of `a` and `b` touch (share a face/edge/corner or
    /// overlap)? Exact in integer coordinates: scale both to the finer
    /// level and compare Chebyshev distance of the index ranges.
    pub fn touches(a: &Cell, b: &Cell) -> bool {
        // Bring both to the finer of the two levels.
        let (fine, coarse) = if a.level >= b.level { (a, b) } else { (b, a) };
        let shift = fine.level - coarse.level;
        let w = 1u64 << shift; // coarse cell width in fine units
        (0..3).all(|d| {
            let f = fine.ix[d] as u64;
            let c0 = (coarse.ix[d] as u64) << shift;
            let c1 = c0 + w - 1; // inclusive fine-index range of coarse box
            // touching iff ranges [f,f] and [c0,c1] are within distance 1
            f + 1 >= c0 && f <= c1 + 1
        })
    }

    /// Is `anc` an ancestor of `c` (or `c` itself)?
    pub fn is_ancestor_of(anc: &Cell, c: &Cell) -> bool {
        if anc.level > c.level {
            return false;
        }
        let shift = c.level - anc.level;
        (0..3).all(|d| (c.ix[d] >> shift) == anc.ix[d])
    }
}

/// The octree: cell arena + the hierarchically sorted particle array.
pub struct Octree {
    pub cells: Vec<Cell>,
    pub parts: Vec<Part>,
    /// Leaf capacity `n_max` used to build the tree.
    pub n_max: usize,
}

/// Root cell id (always 0).
pub const ROOT: CellId = 0;

impl Octree {
    /// Build the octree over `parts` (assumed inside `[0,1)³`), splitting
    /// every cell with more than `n_max` particles (paper §4.2).
    pub fn build(mut parts: Vec<Part>, n_max: usize) -> Self {
        assert!(n_max > 0);
        let n = parts.len();
        let mut cells = vec![Cell {
            loc: [0.0; 3],
            h: 1.0,
            level: 0,
            ix: [0; 3],
            first: 0,
            count: n,
            progeny: None,
            parent: None,
        }];
        let mut stack = vec![ROOT];
        while let Some(ci) = stack.pop() {
            let (first, count, level, ix, loc, h) = {
                let c = &cells[ci];
                (c.first, c.count, c.level, c.ix, c.loc, c.h)
            };
            if count <= n_max {
                continue;
            }
            // 8-way partition of parts[first..first+count] by octant.
            let mid = [loc[0] + h / 2.0, loc[1] + h / 2.0, loc[2] + h / 2.0];
            let octant = |p: &Part| -> usize {
                ((p.x[0] >= mid[0]) as usize) << 2
                    | ((p.x[1] >= mid[1]) as usize) << 1
                    | ((p.x[2] >= mid[2]) as usize)
            };
            let seg = &mut parts[first..first + count];
            let mut counts = [0usize; 8];
            for p in seg.iter() {
                counts[octant(p)] += 1;
            }
            let mut offsets = [0usize; 8];
            for o in 1..8 {
                offsets[o] = offsets[o - 1] + counts[o - 1];
            }
            // Stable counting sort into a scratch buffer (simple and
            // O(count); the recursion totals O(N log N)).
            let mut scratch = vec![Part::default(); seg.len()];
            let mut cursor = offsets;
            for p in seg.iter() {
                let o = octant(p);
                scratch[cursor[o]] = *p;
                cursor[o] += 1;
            }
            seg.copy_from_slice(&scratch);
            // Create the 8 children (even empty ones keep the arithmetic
            // simple; empty cells generate no tasks).
            let mut progeny = [0usize; 8];
            for (o, slot) in progeny.iter_mut().enumerate() {
                let dx = (o >> 2) & 1;
                let dy = (o >> 1) & 1;
                let dz = o & 1;
                let child = Cell {
                    loc: [
                        loc[0] + dx as f64 * h / 2.0,
                        loc[1] + dy as f64 * h / 2.0,
                        loc[2] + dz as f64 * h / 2.0,
                    ],
                    h: h / 2.0,
                    level: level + 1,
                    ix: [
                        ix[0] * 2 + dx as u32,
                        ix[1] * 2 + dy as u32,
                        ix[2] * 2 + dz as u32,
                    ],
                    first: first + offsets[o],
                    count: counts[o],
                    progeny: None,
                    parent: Some(ci),
                };
                let id = cells.len();
                cells.push(child);
                *slot = id;
                if counts[o] > n_max {
                    stack.push(id);
                }
            }
            cells[ci].progeny = Some(progeny);
        }
        Self { cells, parts, n_max }
    }

    pub fn root(&self) -> &Cell {
        &self.cells[ROOT]
    }

    /// All leaf (unsplit, non-empty) cell ids.
    pub fn leaves(&self) -> Vec<CellId> {
        (0..self.cells.len())
            .filter(|&i| !self.cells[i].is_split() && self.cells[i].count > 0)
            .collect()
    }

    /// Verify structural invariants (tests): every split cell's particle
    /// range is the disjoint union of its children's; every particle is
    /// inside its cell's box.
    pub fn check(&self) -> Result<(), String> {
        for (i, c) in self.cells.iter().enumerate() {
            if let Some(pr) = c.progeny {
                let mut covered = 0;
                let mut cursor = c.first;
                for &ch in &pr {
                    let child = &self.cells[ch];
                    if child.first != cursor {
                        return Err(format!("cell {i}: child {ch} not contiguous"));
                    }
                    cursor += child.count;
                    covered += child.count;
                    if child.parent != Some(i) {
                        return Err(format!("cell {i}: child {ch} parent link broken"));
                    }
                }
                if covered != c.count {
                    return Err(format!("cell {i}: children cover {covered}/{}", c.count));
                }
            } else if c.count > self.n_max {
                return Err(format!("leaf {i} overfull: {} > {}", c.count, self.n_max));
            }
            for p in &self.parts[c.first..c.first + c.count] {
                for d in 0..3 {
                    if p.x[d] < c.loc[d] - 1e-12 || p.x[d] > c.loc[d] + c.h + 1e-12 {
                        return Err(format!("particle {} outside cell {i}", p.id));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbody::part::uniform_cloud;

    #[test]
    fn build_small() {
        let tree = Octree::build(uniform_cloud(1000, 4), 100);
        tree.check().unwrap();
        assert!(tree.cells.len() > 1);
        assert_eq!(tree.root().count, 1000);
        // all particles present exactly once (ids are a permutation)
        let mut ids: Vec<u32> = tree.parts.iter().map(|p| p.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn no_split_below_threshold() {
        let tree = Octree::build(uniform_cloud(50, 5), 100);
        assert_eq!(tree.cells.len(), 1);
        assert!(!tree.root().is_split());
    }

    #[test]
    fn uniform_tree_depth_matches_theory() {
        // 4096 uniform particles, n_max=100: expect splits to depth 2
        // (64 cells of ~64) — i.e. 1 + 8 + 64 = 73 cells.
        let tree = Octree::build(uniform_cloud(4096, 6), 100);
        tree.check().unwrap();
        let max_level = tree.cells.iter().map(|c| c.level).max().unwrap();
        assert_eq!(max_level, 2, "cells: {}", tree.cells.len());
        assert_eq!(tree.cells.len(), 73);
    }

    #[test]
    fn touches_same_level() {
        let mk = |level: u32, ix: [u32; 3]| Cell {
            loc: [0.0; 3],
            h: 1.0 / (1 << level) as f64,
            level,
            ix,
            first: 0,
            count: 0,
            progeny: None,
            parent: None,
        };
        let a = mk(2, [1, 1, 1]);
        assert!(Cell::touches(&a, &mk(2, [1, 1, 1])));
        assert!(Cell::touches(&a, &mk(2, [2, 2, 2]))); // corner contact
        assert!(Cell::touches(&a, &mk(2, [0, 1, 2])));
        assert!(!Cell::touches(&a, &mk(2, [3, 1, 1])));
        assert!(!Cell::touches(&a, &mk(2, [1, 3, 3])));
    }

    #[test]
    fn touches_cross_level() {
        let mk = |level: u32, ix: [u32; 3]| Cell {
            loc: [0.0; 3],
            h: 1.0 / (1 << level) as f64,
            level,
            ix,
            first: 0,
            count: 0,
            progeny: None,
            parent: None,
        };
        let coarse = mk(1, [0, 0, 0]); // covers fine ix 0..1 each dim
        assert!(Cell::touches(&coarse, &mk(2, [2, 0, 0]))); // adjacent
        assert!(Cell::touches(&coarse, &mk(2, [1, 1, 1]))); // inside
        assert!(!Cell::touches(&coarse, &mk(2, [3, 0, 0])));
        // symmetric
        assert!(Cell::touches(&mk(2, [2, 0, 0]), &coarse));
    }

    #[test]
    fn ancestor_check() {
        let mk = |level: u32, ix: [u32; 3]| Cell {
            loc: [0.0; 3],
            h: 0.0,
            level,
            ix,
            first: 0,
            count: 0,
            progeny: None,
            parent: None,
        };
        let root = mk(0, [0, 0, 0]);
        let deep = mk(3, [5, 2, 7]);
        assert!(Cell::is_ancestor_of(&root, &deep));
        assert!(Cell::is_ancestor_of(&mk(1, [1, 0, 1]), &deep)); // 5>>2=1, 2>>2=0, 7>>2=1
        assert!(!Cell::is_ancestor_of(&mk(1, [0, 0, 1]), &deep));
        assert!(!Cell::is_ancestor_of(&deep, &root));
        assert!(Cell::is_ancestor_of(&deep, &deep));
    }

    #[test]
    fn leaves_cover_all_particles() {
        let tree = Octree::build(uniform_cloud(3000, 8), 64);
        let total: usize = tree.leaves().iter().map(|&l| tree.cells[l].count).sum();
        assert_eq!(total, 3000);
    }

    #[test]
    fn plummer_tree_is_deeper() {
        let u = Octree::build(uniform_cloud(5000, 1), 50);
        let p = Octree::build(crate::nbody::part::plummer_cloud(5000, 1), 50);
        p.check().unwrap();
        let dmax = |t: &Octree| t.cells.iter().map(|c| c.level).max().unwrap();
        assert!(dmax(&p) > dmax(&u), "clustered cloud must refine deeper");
    }
}
