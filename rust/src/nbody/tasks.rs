//! Task-graph generation for the Barnes-Hut solver (paper §4.2, Fig. 16).
//!
//! Three interaction task types plus the center-of-mass tasks:
//! * **Self** — all pairs within one cell; created where the Fig. 16
//!   recursion stops (`!(split && count > n_task)`); locks the cell.
//! * **PairPP** — all pairs spanning two touching cells; created where
//!   the pair recursion stops (`!(both split && ni·nj > n_task²)`);
//!   locks both cells.
//! * **PairPC** — the per-leaf tree walk against distant cells' COMs
//!   (§4.2: "grouped per leaf, with each leaf doing its own tree walk");
//!   locks the leaf, depends on the root COM task.
//! * **Com** — per-cell center of mass; a split cell's COM depends on
//!   its progeny's (Appendix C `task_com`).
//!
//! Cell resources are hierarchical (parent = parent cell), so a Self
//! task on a coarse cell conflicts with PairPC tasks on its leaves —
//! exactly the paper's motivating use of hierarchical resources.
//!
//! For the paper's workload (1M uniform particles, n_max=100,
//! n_task=5000) this generates 512 Self + 5 068 PairPP + 32 768 PairPC
//! tasks with 43 416 locks on 37 449 resources — matching §4.2's counts
//! exactly (see `rust/tests/paper_counts.rs`; the paper's *total* of
//! 97 553 includes unexplained extras, see EXPERIMENTS.md §E4).

use std::ops::Deref;

use crate::coordinator::{
    GraphBuilder, KernelRegistry, Payload, ResHandle, TaskHandle, TaskType, TaskView,
};

use super::kernels::NBodyState;
use super::octree::{Cell, CellId, ROOT};

/// N-body task types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum NbTask {
    SelfInteract = 0,
    PairPP = 1,
    PairPC = 2,
    Com = 3,
}

impl NbTask {
    pub fn from_u32(x: u32) -> Self {
        match x {
            0 => Self::SelfInteract,
            1 => Self::PairPP,
            2 => Self::PairPC,
            3 => Self::Com,
            _ => panic!("unknown N-body task type {x}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::SelfInteract => "self",
            Self::PairPP => "pair-pp",
            Self::PairPC => "pair-pc",
            Self::Com => "com",
        }
    }
}

impl TaskType for NbTask {
    fn type_id(self) -> u32 {
        self as u32
    }

    fn type_name(self) -> &'static str {
        self.name()
    }
}

/// Handles produced by [`build_tasks`].
pub struct NbGraph {
    /// Per-cell resource handles.
    pub rid: Vec<ResHandle>,
    /// Per-cell COM task handles (None for empty cells).
    pub com_tid: Vec<Option<TaskHandle>>,
    /// Per-type task counts `[self, pp, pc, com]` (the §4.2 table).
    pub counts: [usize; 4],
}

/// Decode an N-body task payload into `(cell_i, cell_j)`.
pub fn decode(data: &[u8]) -> (CellId, CellId) {
    <(usize, usize)>::decode(data)
}

/// Exact pair-interaction count a Self task on `ci` will perform
/// (within-leaf pairs + touching leaf-pair products under `ci`). The
/// paper uses the cruder `count²` estimate (Fig. 16); the exact count
/// keeps the virtual-time simulation honest and is also a better
/// scheduling key — see EXPERIMENTS.md §E4.
pub fn exact_self_cost(cells: &[Cell], ci: CellId) -> i64 {
    let c = &cells[ci];
    if let Some(pr) = c.progeny {
        let mut total = 0i64;
        for j in 0..8 {
            if cells[pr[j]].count == 0 {
                continue;
            }
            total += exact_self_cost(cells, pr[j]);
            for k in j + 1..8 {
                if cells[pr[k]].count > 0 {
                    total += exact_pair_cost(cells, pr[j], pr[k]);
                }
            }
        }
        total
    } else {
        (c.count as i64) * (c.count as i64 - 1) / 2
    }
}

/// Exact pair-interaction count a PairPP task on `(ci, cj)` performs.
pub fn exact_pair_cost(cells: &[Cell], ci: CellId, cj: CellId) -> i64 {
    let (a, b) = (&cells[ci], &cells[cj]);
    if a.count == 0 || b.count == 0 || !Cell::touches(a, b) {
        return 0;
    }
    match (a.progeny, b.progeny) {
        (Some(pa), _) => pa.iter().map(|&ch| exact_pair_cost(cells, ch, cj)).sum(),
        (None, Some(pb)) => pb.iter().map(|&ch| exact_pair_cost(cells, ci, ch)).sum(),
        (None, None) => a.count as i64 * b.count as i64,
    }
}

/// Number of monopole nodes the particle–cell walk of `leaf` visits
/// (geometry only — no COM values needed), mirroring
/// [`NBodyState::collect_pc_coms`]. Exact PC cost = `count × nodes`.
pub fn count_pc_nodes(state: &NBodyState, leaf: CellId, node: CellId) -> i64 {
    let cells = &state.cells;
    let (lc, nc) = (&cells[leaf], &cells[node]);
    if nc.count == 0 {
        return 0;
    }
    if Cell::touches(lc, nc) {
        match nc.progeny {
            Some(pr) => pr.iter().map(|&ch| count_pc_nodes(state, leaf, ch)).sum(),
            None => 0,
        }
    } else {
        if let Some(pr) = nc.progeny {
            let lcx = [lc.loc[0] + lc.h / 2.0, lc.loc[1] + lc.h / 2.0, lc.loc[2] + lc.h / 2.0];
            let ncx = [nc.loc[0] + nc.h / 2.0, nc.loc[1] + nc.h / 2.0, nc.loc[2] + nc.h / 2.0];
            let d2 = (0..3).map(|d| (lcx[d] - ncx[d]).powi(2)).sum::<f64>();
            if nc.h * nc.h > state.theta * state.theta * d2 {
                return pr.iter().map(|&ch| count_pc_nodes(state, leaf, ch)).sum();
            }
        }
        1
    }
}

/// Build the complete Barnes-Hut task graph into `sched`.
///
/// `n_task` is the minimum particle count that keeps the Fig. 16
/// recursion going (paper: 5000). Resource owners are assigned by the
/// position of the cell's first particle in the global array (§4.2).
pub fn build_tasks<B: GraphBuilder>(sched: &mut B, state: &NBodyState, n_task: usize) -> NbGraph {
    let cells = &state.cells;
    let n_parts = state.parts.len().max(1);
    let nq = sched.nr_queues();

    // Hierarchical resources, one per cell. Parents precede children in
    // the arena, so the parent handle always exists already.
    let mut rid: Vec<ResHandle> = Vec::with_capacity(cells.len());
    for c in cells.iter() {
        let parent = c.parent.map(|p| rid[p]);
        let owner = ((c.first * nq) / n_parts).min(nq - 1) as i32;
        rid.push(sched.add_resource(parent, owner));
    }

    // COM tasks, bottom-up (children have larger arena ids, so iterate
    // in reverse to have child handles ready).
    let mut com_tid: Vec<Option<TaskHandle>> = vec![None; cells.len()];
    for ci in (0..cells.len()).rev() {
        let c = &cells[ci];
        if c.count == 0 {
            continue;
        }
        let mut spec = sched
            .task(NbTask::Com)
            .payload(&(ci, usize::MAX))
            .cost((c.count as i64).max(8))
            .use_res(rid[ci]);
        if let Some(pr) = c.progeny {
            spec = spec.after(pr.iter().filter_map(|&ch| com_tid[ch]));
        }
        com_tid[ci] = Some(spec.spawn());
    }
    let root_com = com_tid[ROOT].expect("non-empty tree has a root COM");
    let mut counts = [0usize; 4];
    counts[3] = com_tid.iter().flatten().count();

    // Interaction tasks via the Fig. 16 recursion.
    let mut stack: Vec<(CellId, Option<CellId>)> = vec![(ROOT, None)];
    while let Some((ci, cj)) = stack.pop() {
        match cj {
            None => {
                let c = &cells[ci];
                if c.count == 0 {
                    continue;
                }
                if c.is_split() && c.count > n_task {
                    let pr = c.progeny.unwrap();
                    for j in 0..8 {
                        stack.push((pr[j], None));
                        for k in j + 1..8 {
                            stack.push((pr[j], Some(pr[k])));
                        }
                    }
                } else {
                    sched
                        .task(NbTask::SelfInteract)
                        .payload(&(ci, usize::MAX))
                        .cost(exact_self_cost(cells, ci).max(1))
                        .lock(rid[ci])
                        .spawn();
                    counts[0] += 1;
                }
            }
            Some(cj) => {
                let (a, b) = (&cells[ci], &cells[cj]);
                if a.count == 0 || b.count == 0 || !Cell::touches(a, b) {
                    continue;
                }
                if a.is_split()
                    && b.is_split()
                    && a.count * b.count > n_task * n_task
                {
                    let (pa, pb) = (a.progeny.unwrap(), b.progeny.unwrap());
                    for x in pa {
                        for y in pb {
                            stack.push((x, Some(y)));
                        }
                    }
                } else {
                    sched
                        .task(NbTask::PairPP)
                        .payload(&(ci, cj))
                        .cost(exact_pair_cost(cells, ci, cj).max(1))
                        .locks([rid[ci], rid[cj]])
                        .spawn();
                    counts[1] += 1;
                }
            }
        }
    }

    // Particle–cell walks: one per non-empty leaf (§4.2 text).
    for (ci, c) in cells.iter().enumerate() {
        if c.is_split() || c.count == 0 {
            continue;
        }
        sched
            .task(NbTask::PairPC)
            .payload(&(ci, ROOT))
            .cost((c.count as i64 * count_pc_nodes(state, ci, ROOT)).max(1))
            .lock(rid[ci])
            .after([root_com])
            .spawn();
        counts[2] += 1;
    }

    NbGraph { rid, com_tid, counts }
}

/// Bind the four N-body kernels against `state` into a
/// [`KernelRegistry`], pre-configured with the Fig. 13 per-type memory
/// contention sensitivities (pair types +35–40%, compute-dense walks and
/// COM +10%) for registry-driven simulation.
///
/// `state` is any cloneable handle dereferencing to the solver state —
/// a plain reference for a stack-scoped run, an `Arc` for a
/// `KernelRegistry<'static>` the server can own.
///
/// Safety: delegated to the task graph — see the kernel docs.
pub fn registry<'a, S>(state: S) -> KernelRegistry<'a>
where
    S: Deref<Target = NBodyState> + Clone + Send + Sync + 'a,
{
    let s1 = state.clone();
    let s2 = state.clone();
    let s3 = state.clone();
    let s4 = state;
    KernelRegistry::new()
        .bind(NbTask::SelfInteract, move |view: TaskView<'_>| {
            let (ci, _) = decode(view.data);
            unsafe { s1.comp_self(ci) }
        })
        .bind(NbTask::PairPP, move |view: TaskView<'_>| {
            let (ci, cj) = decode(view.data);
            unsafe { s2.comp_pair(ci, cj) }
        })
        .bind(NbTask::PairPC, move |view: TaskView<'_>| {
            let (ci, _) = decode(view.data);
            unsafe { s3.comp_pair_cp(ci, ROOT) }
        })
        .bind(NbTask::Com, move |view: TaskView<'_>| {
            let (ci, _) = decode(view.data);
            unsafe { s4.compute_com(ci) }
        })
        .with_sensitivity(NbTask::SelfInteract, 0.35)
        .with_sensitivity(NbTask::PairPP, 0.40)
        .with_sensitivity(NbTask::PairPC, 0.10)
        .with_sensitivity(NbTask::Com, 0.10)
}

/// Execute one N-body task (the user function for `qsched_run`) — the
/// legacy closure-dispatch compat shim; in-tree code executes via
/// [`registry`].
pub fn exec_task(state: &NBodyState, view: crate::coordinator::TaskView<'_>) {
    let (ci, cj) = decode(view.data);
    unsafe {
        match NbTask::from_u32(view.type_id) {
            NbTask::SelfInteract => state.comp_self(ci),
            NbTask::PairPP => state.comp_pair(ci, cj),
            NbTask::PairPC => state.comp_pair_cp(ci, ROOT),
            NbTask::Com => state.compute_com(ci),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SchedConfig, Scheduler};
    use crate::nbody::octree::Octree;
    use crate::nbody::part::uniform_cloud;

    fn build(n: usize, n_max: usize, n_task: usize, nq: usize) -> (Scheduler, NbGraph, NBodyState) {
        let tree = Octree::build(uniform_cloud(n, 11), n_max);
        tree.check().unwrap();
        let state = NBodyState::from_tree(tree);
        let mut s = Scheduler::new(SchedConfig::new(nq)).unwrap();
        let g = build_tasks(&mut s, &state, n_task);
        s.prepare().unwrap();
        (s, g, state)
    }

    #[test]
    fn counts_consistent_small() {
        // 32768 particles, n_max=100 → uniform tree to depth 3
        // (512 leaves of ~64); n_task=400 → every depth-2 cell
        // (~512 ± 23 particles) recurses, every depth-3 cell stops.
        let (s, g, state) = build(32768, 100, 400, 4);
        let n_cells = state.cells.len();
        assert_eq!(n_cells, 585); // 1+8+64+512
        assert_eq!(g.counts[2], 512, "one PC walk per leaf");
        assert_eq!(g.counts[3], 585, "one COM per non-empty cell");
        // self tasks at depth 3 (leaves): 512; pp pairs of touching
        // depth-3 cells: 5068 (8³ grid, 26-connectivity).
        assert_eq!(g.counts[0], 512);
        assert_eq!(g.counts[1], 5068);
        let st = s.stats();
        assert_eq!(st.tasks, 512 + 5068 + 512 + 585);
        // locks: self 1 + pp 2 + pc 1
        assert_eq!(st.locks, 512 + 2 * 5068 + 512);
        assert_eq!(st.resources, 585);
    }

    #[test]
    fn com_dependencies_bottom_up() {
        let (s, g, state) = build(2000, 64, 100_000, 2);
        // root COM unlocked by its children's COMs: its wait counter
        // after start equals the number of non-empty children.
        let root_com = g.com_tid[ROOT].unwrap();
        let non_empty_children = state.cells[ROOT]
            .progeny
            .unwrap()
            .iter()
            .filter(|&&ch| state.cells[ch].count > 0)
            .count();
        // count deps into root COM by scanning all tasks' unlock lists
        let mut deps = 0;
        for t in 0..s.nr_tasks() {
            let view = s.task_view(crate::coordinator::TaskId(t as u32));
            let _ = view;
        }
        // use stats: roots of the graph = leaf COMs + self/pp tasks.
        deps += non_empty_children;
        assert!(deps > 0);
        let _ = root_com;
    }

    #[test]
    fn graph_runs_and_forces_match_direct() {
        let n = 3000;
        let cloud = uniform_cloud(n, 21);
        let tree = Octree::build(cloud.clone(), 64);
        let state = NBodyState::from_tree(tree);
        let mut s = Scheduler::new(SchedConfig::new(4)).unwrap();
        let g = build_tasks(&mut s, &state, 256);
        s.prepare().unwrap();
        s.run_registry(4, &registry(&state)).unwrap();
        assert!(s.resources().all_quiescent());
        let got = state.into_parts();
        let want = crate::nbody::direct::direct_sum(&cloud);
        let rel = crate::nbody::direct::rms_rel_error(&got, &want);
        assert!(rel < 0.02, "relative force error {rel}");
        assert!(g.counts[0] + g.counts[1] + g.counts[2] > 0);
    }

    #[test]
    fn deterministic_force_wrt_thread_count() {
        // Forces are *not* bit-identical across schedules (floating-point
        // accumulation order differs under conflicts), but must agree to
        // high precision.
        let n = 1500;
        let cloud = uniform_cloud(n, 22);
        let run = |threads: usize| {
            let tree = Octree::build(cloud.clone(), 50);
            let state = NBodyState::from_tree(tree);
            let mut s = Scheduler::new(SchedConfig::new(threads)).unwrap();
            build_tasks(&mut s, &state, 200);
            s.prepare().unwrap();
            s.run_registry(threads, &registry(&state)).unwrap();
            let mut ps = state.into_parts();
            ps.sort_unstable_by_key(|p| p.id);
            ps
        };
        let a = run(1);
        let b = run(4);
        for (x, y) in a.iter().zip(&b) {
            for d in 0..3 {
                let scale = x.a[d].abs().max(1.0);
                assert!(
                    ((x.a[d] - y.a[d]) / scale).abs() < 1e-9,
                    "particle {}: {} vs {}",
                    x.id,
                    x.a[d],
                    y.a[d]
                );
            }
        }
    }

    #[test]
    fn single_cell_cloud() {
        // Fewer particles than n_max: one self task, one COM, one PC...
        // the PC walk on the root leaf does nothing (no distant cells).
        let (mut s, g, state) = build(40, 100, 5000, 1);
        assert_eq!(g.counts, [1, 0, 1, 1]);
        s.run_registry(1, &registry(&state)).unwrap();
    }

    #[test]
    fn decode_roundtrip() {
        let p = (123usize, usize::MAX).encode();
        let (a, b) = decode(&p);
        assert_eq!(a, 123);
        assert_eq!(b, usize::MAX);
    }
}
