//! Task-based Barnes-Hut N-body substrate (paper §4.2).
//!
//! An octree with hierarchically sorted contiguous particle storage
//! (Fig. 10), three interaction task types plus per-cell COM tasks
//! (Fig. 16), conflicts via hierarchical cell resources, a direct-sum
//! oracle, and a traditional per-particle treewalk baseline (the
//! Gadget-2 stand-in of Fig. 11).
pub mod baseline;
pub mod direct;
pub mod driver;
pub mod kernels;
pub mod octree;
pub mod part;
pub mod tasks;

pub use driver::{nb_cost_model, run_sim, run_threaded, NbRun, NbScale};
pub use kernels::NBodyState;
pub use octree::{Cell, CellId, Octree, ROOT};
pub use part::{plummer_cloud, uniform_cloud, Part};
pub use tasks::{build_tasks, exec_task, registry, NbGraph, NbTask};
