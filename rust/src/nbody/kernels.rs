//! N-body interaction kernels (paper Fig. 15): self-interaction,
//! particle–particle pair interaction, and the per-leaf particle–cell
//! tree-walk. Plain Newtonian gravity with G = 1 and Plummer softening
//! ε (the direct-sum oracle uses the same force law, so the only error
//! the verification sees is the multipole approximation).
//!
//! These natives are mirrored by the Pallas kernels in
//! `python/compile/kernels/nbody.py` (checked against `ref.py` by
//! pytest, and against these natives by `rust/tests/xla_backend.rs`).

use super::octree::{Cell, CellId, Octree};
use super::part::Part;
use crate::util::shared::SharedGrid;

/// Softening length: small vs. the mean inter-particle distance of the
/// paper's workload (1M in a unit box → ~0.01), so forces stay finite
/// without altering the large-scale physics.
pub const EPS2: f64 = 1e-10;

/// Accumulate the pairwise acceleration of `pi` and `pj` on both.
#[inline]
pub fn interact(pi: &mut Part, pj: &mut Part) {
    let dx = [
        pj.x[0] - pi.x[0],
        pj.x[1] - pi.x[1],
        pj.x[2] - pi.x[2],
    ];
    let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
    let inv_r = 1.0 / r2.sqrt();
    let inv_r3 = inv_r * inv_r * inv_r;
    let wi = pj.mass * inv_r3;
    let wj = pi.mass * inv_r3;
    for d in 0..3 {
        pi.a[d] += wi * dx[d];
        pj.a[d] -= wj * dx[d];
    }
}

/// Accumulate the acceleration of a point mass `(com, mass)` on `p`.
#[inline]
pub fn interact_com(p: &mut Part, com: &[f64; 3], mass: f64) {
    let dx = [com[0] - p.x[0], com[1] - p.x[1], com[2] - p.x[2]];
    let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
    let inv_r = 1.0 / r2.sqrt();
    let w = mass * inv_r3(inv_r);
    for d in 0..3 {
        p.a[d] += w * dx[d];
    }
}

#[inline]
fn inv_r3(inv_r: f64) -> f64 {
    inv_r * inv_r * inv_r
}

/// Center-of-mass storage: `[x, y, z, mass]` per cell, written by the
/// COM tasks and read by the particle–cell walks.
pub type ComTable = SharedGrid<[f64; 4]>;

/// Shared N-body state during a parallel run. Particle accelerations are
/// mutated under the task graph's cell locks; positions/masses are
/// read-only; COMs are written by the COM task of the owning cell before
/// (dependency-ordered) any reader runs.
pub struct NBodyState {
    pub cells: Vec<Cell>,
    pub parts: SharedGrid<Part>,
    pub coms: ComTable,
    pub n_max: usize,
    /// Opening-angle refinement for the particle–cell walk: a
    /// non-touching *split* cell is descended (instead of taking its
    /// monopole) while `h > θ·d`. θ = ∞ reproduces the paper's pure
    /// adjacency criterion; the default 0.65 bounds the worst-case
    /// effective opening angle for deep leaves next to coarse cells
    /// (relevant for clustered, non-uniform trees).
    pub theta: f64,
}

impl NBodyState {
    pub fn from_tree(tree: Octree) -> Self {
        let ncells = tree.cells.len();
        Self {
            cells: tree.cells,
            parts: SharedGrid::from_vec(tree.parts),
            coms: SharedGrid::from_vec(vec![[0.0; 4]; ncells]),
            n_max: tree.n_max,
            theta: 0.65,
        }
    }

    /// Take the particles back out (after a run).
    pub fn into_parts(self) -> Vec<Part> {
        self.parts.into_vec()
    }

    /// # Safety
    /// Caller must hold (transitively, via the task graph) exclusive
    /// access to the particles of cell `ci`.
    #[allow(clippy::mut_from_ref)]
    unsafe fn parts_mut(&self, ci: CellId) -> &mut [Part] {
        let c = &self.cells[ci];
        self.parts.slice_mut(c.first, c.first + c.count)
    }

    /// Compute the COM of cell `ci` (the tCOM task): mass-weighted
    /// average of progeny COMs (split) or of own particles (leaf).
    ///
    /// # Safety
    /// Progeny COMs must already be computed (dependency-ordered), and no
    /// one may be writing this cell's COM concurrently.
    pub unsafe fn compute_com(&self, ci: CellId) {
        let c = &self.cells[ci];
        let mut acc = [0.0f64; 4];
        if let Some(pr) = c.progeny {
            for ch in pr {
                let com = self.coms.get(ch);
                acc[3] += com[3];
                for d in 0..3 {
                    acc[d] += com[d] * com[3];
                }
            }
        } else {
            for p in self.parts.slice(c.first, c.first + c.count) {
                acc[3] += p.mass;
                for d in 0..3 {
                    acc[d] += p.x[d] * p.mass;
                }
            }
        }
        if acc[3] > 0.0 {
            for d in 0..3 {
                acc[d] /= acc[3];
            }
        }
        *self.coms.get_mut(ci) = acc;
    }

    /// Self-interaction task (Fig. 15 `comp_self`): all pairs within
    /// `ci`, recursing into split cells and skipping non-touching child
    /// pairs (those are covered by the particle–cell walks).
    ///
    /// # Safety
    /// The task graph must hold the lock on `ci`'s resource.
    pub unsafe fn comp_self(&self, ci: CellId) {
        let c = &self.cells[ci];
        if let Some(pr) = c.progeny {
            for j in 0..8 {
                if self.cells[pr[j]].count == 0 {
                    continue;
                }
                self.comp_self(pr[j]);
                for k in j + 1..8 {
                    if self.cells[pr[k]].count > 0 {
                        self.comp_pair(pr[j], pr[k]);
                    }
                }
            }
        } else {
            let ps = self.parts_mut(ci);
            for j in 0..ps.len() {
                let (a, b) = ps.split_at_mut(j + 1);
                let pj = &mut a[j];
                for pk in b.iter_mut() {
                    interact(pj, pk);
                }
            }
        }
    }

    /// Pair-interaction task (Fig. 15 `comp_pair`): if the cells do not
    /// touch, nothing (covered by the tree walk); while either cell is
    /// split, recurse into its children (touch-filtered); once both are
    /// leaves, direct double loop.
    ///
    /// # Safety
    /// The task graph must hold the locks on both cells' resources.
    pub unsafe fn comp_pair(&self, ci: CellId, cj: CellId) {
        let (a, b) = (&self.cells[ci], &self.cells[cj]);
        if a.count == 0 || b.count == 0 || !Cell::touches(a, b) {
            return;
        }
        match (a.progeny, b.progeny) {
            (Some(pa), _) => {
                for ch in pa {
                    self.comp_pair(ch, cj);
                }
            }
            (None, Some(pb)) => {
                for ch in pb {
                    self.comp_pair(ci, ch);
                }
            }
            (None, None) => {
                // Two disjoint leaf ranges of the same array.
                let ps_i = self.parts_mut(ci);
                let ps_j = self.parts_mut(cj);
                for pi in ps_i.iter_mut() {
                    for pj in ps_j.iter_mut() {
                        interact(pi, pj);
                    }
                }
            }
        }
    }

    /// Particle–cell task (Fig. 15 `comp_pair_cp`): the per-leaf tree
    /// walk. Starting from `node` (the root), descend while the node's
    /// box touches the leaf's; interact the leaf's particles with the COM
    /// of every non-touching node at the coarsest level; skip touching
    /// leaves (covered by self/pair tasks).
    ///
    /// # Safety
    /// The task graph must hold the lock on `leaf`'s resource, and all
    /// COMs must be computed (the task depends on the root COM task).
    pub unsafe fn comp_pair_cp(&self, leaf: CellId, node: CellId) {
        let lc = &self.cells[leaf];
        let nc = &self.cells[node];
        if nc.count == 0 {
            return;
        }
        if Cell::touches(lc, nc) {
            if let Some(pr) = nc.progeny {
                for ch in pr {
                    self.comp_pair_cp(leaf, ch);
                }
            }
            // touching leaf (or the leaf itself): exact interactions are
            // handled by the self/pair tasks.
        } else {
            // θ-refinement: a split non-touching cell that is still
            // "large" relative to its distance is descended. Children of
            // a non-touching cell never touch the leaf, so coverage is
            // unchanged — only the approximation level improves.
            if let Some(pr) = nc.progeny {
                let lcx = [
                    lc.loc[0] + lc.h / 2.0,
                    lc.loc[1] + lc.h / 2.0,
                    lc.loc[2] + lc.h / 2.0,
                ];
                let ncx = [
                    nc.loc[0] + nc.h / 2.0,
                    nc.loc[1] + nc.h / 2.0,
                    nc.loc[2] + nc.h / 2.0,
                ];
                let d2 = (0..3).map(|d| (lcx[d] - ncx[d]).powi(2)).sum::<f64>();
                if nc.h * nc.h > self.theta * self.theta * d2 {
                    for ch in pr {
                        self.comp_pair_cp(leaf, ch);
                    }
                    return;
                }
            }
            let com = *self.coms.get(node);
            let ps = self.parts_mut(leaf);
            for p in ps.iter_mut() {
                interact_com(p, &[com[0], com[1], com[2]], com[3]);
            }
        }
    }

    /// Enumerate, without interacting, the `[x, y, z, mass]` monopoles
    /// the particle–cell walk of `leaf` would use — the same branching
    /// as [`Self::comp_pair_cp`]. Used by the XLA backend to batch the
    /// walk into fixed-shape kernel calls.
    ///
    /// # Safety
    /// All COMs must be computed (the PC task depends on the root COM).
    pub unsafe fn collect_pc_coms(&self, leaf: CellId, node: CellId, out: &mut Vec<[f64; 4]>) {
        let lc = &self.cells[leaf];
        let nc = &self.cells[node];
        if nc.count == 0 {
            return;
        }
        if Cell::touches(lc, nc) {
            if let Some(pr) = nc.progeny {
                for ch in pr {
                    self.collect_pc_coms(leaf, ch, out);
                }
            }
        } else {
            if let Some(pr) = nc.progeny {
                let lcx = [
                    lc.loc[0] + lc.h / 2.0,
                    lc.loc[1] + lc.h / 2.0,
                    lc.loc[2] + lc.h / 2.0,
                ];
                let ncx = [
                    nc.loc[0] + nc.h / 2.0,
                    nc.loc[1] + nc.h / 2.0,
                    nc.loc[2] + nc.h / 2.0,
                ];
                let d2 = (0..3).map(|d| (lcx[d] - ncx[d]).powi(2)).sum::<f64>();
                if nc.h * nc.h > self.theta * self.theta * d2 {
                    for ch in pr {
                        self.collect_pc_coms(leaf, ch, out);
                    }
                    return;
                }
            }
            out.push(*self.coms.get(node));
        }
    }
}

/// Count the pair-interactions a task would perform — the paper's task
/// cost estimates (`count²` for self, `count_i × count_j` for pairs,
/// `count` for particle–cell; Fig. 16 lines 15, 19, 31).
pub mod cost {
    use super::*;

    pub fn self_cost(c: &Cell) -> i64 {
        (c.count as i64).pow(2)
    }

    pub fn pair_cost(a: &Cell, b: &Cell) -> i64 {
        a.count as i64 * b.count as i64
    }

    pub fn pc_cost(leaf: &Cell) -> i64 {
        // One COM interaction per particle per opened node; the paper
        // uses plain `count`. We scale by a nominal walk length so the
        // relative cost vs pair tasks is comparable.
        leaf.count as i64 * 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbody::octree::{Octree, ROOT};
    use crate::nbody::part::uniform_cloud;

    #[test]
    fn interact_is_antisymmetric_in_force() {
        let mut a = Part::at([0.0, 0.0, 0.0], 2.0, 0);
        let mut b = Part::at([1.0, 0.0, 0.0], 3.0, 1);
        interact(&mut a, &mut b);
        // F = G m1 m2 / r² = 6; a_a = 3, a_b = -2 along x.
        assert!((a.a[0] - 3.0).abs() < 1e-9);
        assert!((b.a[0] + 2.0).abs() < 1e-9);
        // momentum conservation: m_a a_a + m_b a_b = 0
        assert!((a.a[0] * 2.0 + b.a[0] * 3.0).abs() < 1e-12);
        assert_eq!(a.a[1], 0.0);
    }

    #[test]
    fn interact_com_matches_unit_particle() {
        let mut p1 = Part::at([0.2, 0.3, 0.4], 1.0, 0);
        let mut p2 = p1;
        let mut src = Part::at([0.7, 0.1, 0.9], 5.0, 1);
        interact(&mut p1, &mut src);
        interact_com(&mut p2, &[0.7, 0.1, 0.9], 5.0);
        for d in 0..3 {
            assert!((p1.a[d] - p2.a[d]).abs() < 1e-14);
        }
    }

    #[test]
    fn com_of_leaf_and_split_agree() {
        let tree = Octree::build(uniform_cloud(500, 2), 50);
        let state = NBodyState::from_tree(tree);
        // compute leaf COMs then inner cells bottom-up (reverse arena
        // order works: children always have larger ids than parents).
        unsafe {
            for ci in (0..state.cells.len()).rev() {
                state.compute_com(ci);
            }
            let root_com = *state.coms.get(ROOT);
            // Direct COM over all particles.
            let ps = state.parts.slice(0, 500);
            let mut acc = [0.0; 4];
            for p in ps {
                acc[3] += p.mass;
                for d in 0..3 {
                    acc[d] += p.x[d] * p.mass;
                }
            }
            for d in 0..3 {
                acc[d] /= acc[3];
            }
            for d in 0..3 {
                assert!((root_com[d] - acc[d]).abs() < 1e-12);
            }
            assert!((root_com[3] - acc[3]).abs() < 1e-12);
        }
    }

    #[test]
    fn comp_self_on_leaf_equals_direct() {
        // A single unsplit cell: comp_self must equal the direct sum.
        let cloud = uniform_cloud(80, 3);
        let tree = Octree::build(cloud.clone(), 100);
        assert!(!tree.root().is_split());
        let state = NBodyState::from_tree(tree);
        unsafe { state.comp_self(ROOT) };
        let got = state.into_parts();
        let want = crate::nbody::direct::direct_sum(&cloud);
        for g in &got {
            let w = &want[g.id as usize];
            for d in 0..3 {
                assert!(
                    (g.a[d] - w.a[d]).abs() < 1e-10 * w.a[d].abs().max(1.0),
                    "particle {} dim {d}: {} vs {}",
                    g.id,
                    g.a[d],
                    w.a[d]
                );
            }
        }
    }

    #[test]
    fn comp_self_on_split_cell_plus_walk_equals_direct() {
        // Full pipeline on a split tree, sequential: COMs, self at root
        // (which recurses into touching pairs), then the per-leaf walks.
        let cloud = uniform_cloud(600, 7);
        let tree = Octree::build(cloud.clone(), 64);
        assert!(tree.root().is_split());
        let leaves = tree.leaves();
        let state = NBodyState::from_tree(tree);
        unsafe {
            for ci in (0..state.cells.len()).rev() {
                state.compute_com(ci);
            }
            state.comp_self(ROOT);
            for &l in &leaves {
                state.comp_pair_cp(l, ROOT);
            }
        }
        let got = state.into_parts();
        let want = crate::nbody::direct::direct_sum(&cloud);
        // Approximation error: touching-cell pairs are exact, distant
        // cells are monopole — typical relative force error well below
        // a few percent for uniform clouds.
        let mut num = 0.0;
        let mut den = 0.0;
        for g in &got {
            let w = &want[g.id as usize];
            for d in 0..3 {
                num += (g.a[d] - w.a[d]).powi(2);
                den += w.a[d].powi(2);
            }
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.02, "relative force error {rel}");
        assert!(rel > 0.0, "walk must actually approximate something");
    }

    #[test]
    fn costs_match_definitions() {
        let c = Cell {
            loc: [0.0; 3],
            h: 1.0,
            level: 0,
            ix: [0; 3],
            first: 0,
            count: 10,
            progeny: None,
            parent: None,
        };
        assert_eq!(cost::self_cost(&c), 100);
        assert_eq!(cost::pair_cost(&c, &c), 100);
        assert_eq!(cost::pc_cost(&c), 640);
    }
}
