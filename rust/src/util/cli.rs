//! Minimal command-line parsing (no `clap` in the offline registry):
//! positional arguments plus `--key value` / `--flag` options.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an argument list. `--key value` becomes an option when the
    /// next token is not itself `--`-prefixed, otherwise a flag.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Self::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("qr --tiles 32 --verify --backend xla");
        assert_eq!(a.positional, vec!["qr"]);
        assert_eq!(a.get_usize("tiles", 0), 32);
        assert!(a.flag("verify"));
        assert_eq!(a.get_str("backend", "native"), "xla");
        assert_eq!(a.get_usize("threads", 4), 4);
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--quick --n 100");
        assert!(a.flag("quick"));
        assert_eq!(a.get_usize("n", 0), 100);
    }

    #[test]
    #[should_panic(expected = "expects a number")]
    fn bad_number_panics() {
        parse("--tiles abc").get_usize("tiles", 0);
    }
}
