//! Utilities: deterministic RNG, statistics, shared-memory cells.
pub mod cli;
pub mod rng;
pub mod shared;
pub mod stats;
