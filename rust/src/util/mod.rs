//! Utilities: deterministic RNG, statistics, shared-memory cells,
//! cache-line padding.
pub mod cli;
pub mod pad;
pub mod rng;
pub mod shared;
pub mod stats;
