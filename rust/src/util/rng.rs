//! Small deterministic PRNGs (the registry has no `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar`, which is the workhorse for
//! workload generation, random steal order (paper §3.4), and the property
//! tests. All generators are deterministic from their seed so every
//! experiment in EXPERIMENTS.md is exactly reproducible.

/// SplitMix64: used to expand a 64-bit seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child seed from a root seed and a stream
    /// id. This is the single derivation rule for every server-side RNG
    /// (worker steal walks, the simulator's fault/interleave streams):
    /// one root `u64` fans out into decorrelated streams, so a whole
    /// run is reproducible from the root alone. Two SplitMix64 steps
    /// keep adjacent stream ids (0, 1, 2, …) from yielding correlated
    /// xoshiro states.
    #[inline]
    pub fn split(root: u64, stream: u64) -> u64 {
        let mut sm = SplitMix64::new(root ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        sm.next_u64().wrapping_add(sm.next_u64().rotate_left(17))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (two uniforms per pair; we waste one).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`, used for random-order work stealing.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Visit order of a random cyclic permutation of `0..n`: a random
    /// start plus a stride coprime to `n`, so every index is yielded
    /// exactly once in O(1) state and without allocating (§Perf opt C).
    /// This is the random-order probe of the paper's §3.4 work
    /// stealing, shared by `Scheduler::gettask`, the server's shard
    /// pool, and the virtual-time sharded executor — one definition so
    /// the three walks can never diverge.
    ///
    /// `n` must be > 0; callers skip the walk entirely when there is
    /// only one candidate (`n == 1` would still consume two draws).
    pub fn coprime_walk(&mut self, n: usize) -> CoprimeWalk {
        debug_assert!(n > 0);
        let start = self.index(n);
        let step = if n > 1 {
            let mut s = 1 + self.index(n - 1);
            while gcd(s, n) != 1 {
                s = 1 + (s % (n - 1));
            }
            s
        } else {
            1
        };
        CoprimeWalk { next: start, step, n, remaining: n }
    }
}

/// Iterator over a random cyclic permutation of `0..n`; see
/// [`Rng::coprime_walk`].
pub struct CoprimeWalk {
    next: usize,
    step: usize,
    n: usize,
    remaining: usize,
}

impl Iterator for CoprimeWalk {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        let k = self.next;
        self.next = (self.next + self.step) % self.n;
        self.remaining -= 1;
        Some(k)
    }
}

#[inline]
fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn coprime_walk_visits_everything_once() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 3, 4, 6, 7, 12, 64] {
            for _ in 0..8 {
                let mut seen = vec![false; n];
                for k in rng.coprime_walk(n) {
                    assert!(k < n);
                    assert!(!seen[k], "index {k} visited twice for n={n}");
                    seen[k] = true;
                }
                assert!(seen.iter().all(|&s| s), "walk missed an index for n={n}");
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        for n in [0usize, 1, 2, 17, 100] {
            let mut p = r.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
