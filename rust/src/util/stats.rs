//! Summary statistics used by the bench drivers (no `criterion` offline —
//! the repo ships its own measurement harness, see [`crate::bench::harness`]).

/// Online mean/variance via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Full-sample summary: mean, stddev, min, max, median, percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Self {
            n: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            median: percentile_sorted(&sorted, 50.0),
            p10: percentile_sorted(&sorted, 10.0),
            p90: percentile_sorted(&sorted, 90.0),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean, used for speedup aggregation across experiments.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_single_sample() {
        let mut w = Welford::new();
        w.push(5.0);
        assert_eq!(w.mean(), 5.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentile_endpoints() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 4.0);
        assert!((percentile_sorted(&s, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
    }
}
