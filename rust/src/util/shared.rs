//! Shared-memory containers whose exclusivity is guaranteed by the
//! *scheduler's* constraints rather than by rust's borrow checker.
//!
//! The paper's applications mutate a shared matrix / particle array from
//! many threads, relying on task dependencies and resource locks to make
//! each access exclusive. [`SharedGrid`] encodes that contract: it hands
//! out raw mutable access, and the *caller* promises that the scheduler's
//! dependency + conflict constraints serialize conflicting accesses
//! (which the property tests in `rust/tests/` verify independently).

use std::cell::UnsafeCell;

/// A fixed-size grid of `T` cells mutable from multiple workers under
/// scheduler-enforced exclusivity.
pub struct SharedGrid<T> {
    cells: Vec<UnsafeCell<T>>,
}

// SAFETY: access discipline is delegated to the task scheduler; see the
// module docs. All methods that touch cells are `unsafe` and spell out
// the proof obligation.
unsafe impl<T: Send> Sync for SharedGrid<T> {}
unsafe impl<T: Send> Send for SharedGrid<T> {}

impl<T> SharedGrid<T> {
    pub fn from_vec(v: Vec<T>) -> Self {
        Self { cells: v.into_iter().map(UnsafeCell::new).collect() }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Mutable access to cell `i`.
    ///
    /// # Safety
    /// The caller must guarantee — via task dependencies and/or resource
    /// locks — that no other thread accesses cell `i` concurrently.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        &mut *self.cells[i].get()
    }

    /// Shared read of cell `i`.
    ///
    /// # Safety
    /// The caller must guarantee that no other thread *writes* cell `i`
    /// concurrently (concurrent reads are fine).
    #[inline]
    pub unsafe fn get(&self, i: usize) -> &T {
        &*self.cells[i].get()
    }

    /// Mutable access to the contiguous sub-slice `lo..hi` (the
    /// Barnes-Hut cells address their particles as ranges of one global
    /// array, Fig. 10 of the paper).
    ///
    /// # Safety
    /// The caller must guarantee — via task dependencies and/or resource
    /// locks — that no other thread accesses any cell in `lo..hi`
    /// concurrently. `UnsafeCell<T>` is layout-identical to `T`, so the
    /// cast below is sound once exclusivity holds.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.cells.len());
        std::slice::from_raw_parts_mut(self.cells[lo..hi].as_ptr() as *mut T, hi - lo)
    }

    /// Shared read of the contiguous sub-slice `lo..hi`.
    ///
    /// # Safety
    /// No other thread may *write* any cell in `lo..hi` concurrently.
    #[inline]
    pub unsafe fn slice(&self, lo: usize, hi: usize) -> &[T] {
        debug_assert!(lo <= hi && hi <= self.cells.len());
        std::slice::from_raw_parts(self.cells[lo..hi].as_ptr() as *const T, hi - lo)
    }

    /// Exclusive access to the whole grid; safe because it borrows `self`
    /// mutably (no scheduler involved).
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: &mut self gives unique access to every cell.
        unsafe {
            std::slice::from_raw_parts_mut(self.cells.as_mut_ptr() as *mut T, self.cells.len())
        }
    }

    /// Shared snapshot of the whole grid; safe because it borrows `self`
    /// mutably forbidding concurrent task access.
    pub fn as_slice(&mut self) -> &[T] {
        self.as_mut_slice()
    }

    /// Consume the grid, returning the underlying values.
    pub fn into_vec(self) -> Vec<T> {
        self.cells.into_iter().map(UnsafeCell::into_inner).collect()
    }
}

impl<T: Clone> SharedGrid<T> {
    pub fn new(n: usize, init: T) -> Self {
        Self::from_vec(vec![init; n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut g = SharedGrid::new(4, 0i64);
        unsafe {
            *g.get_mut(2) = 7;
        }
        assert_eq!(g.as_slice(), &[0, 0, 7, 0]);
        assert_eq!(g.into_vec(), vec![0, 0, 7, 0]);
    }

    #[test]
    fn from_vec_preserves_order() {
        let mut g = SharedGrid::from_vec(vec![1, 2, 3]);
        assert_eq!(g.as_slice(), &[1, 2, 3]);
        assert_eq!(g.len(), 3);
        assert!(!g.is_empty());
    }

    #[test]
    fn disjoint_parallel_writes() {
        // Each thread writes its own stripe — the pattern the QR tiles use.
        let g = std::sync::Arc::new(SharedGrid::new(64, 0u64));
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let g = std::sync::Arc::clone(&g);
            hs.push(std::thread::spawn(move || {
                for i in (t as usize * 16)..((t as usize + 1) * 16) {
                    unsafe { *g.get_mut(i) = t + 1 };
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut g = std::sync::Arc::try_unwrap(g).ok().unwrap();
        let s = g.as_slice();
        for t in 0..4 {
            assert!(s[t * 16..(t + 1) * 16].iter().all(|&x| x == t as u64 + 1));
        }
    }
}
