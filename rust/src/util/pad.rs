//! Cache-line padding for hot atomics.
//!
//! Counters that are bumped from every worker (queue spin-locks,
//! `QueueStats`, per-resource lock words, per-task wait counters) must
//! not share a 64-byte line with an unrelated hot word, or every bump
//! invalidates the neighbor's line on every other core (false sharing).
//! `CachePadded<T>` aligns its contents to a 64-byte boundary, which —
//! because alignment also rounds the *size* up to a multiple of the
//! alignment — gives each wrapped value a cache line of its own.
//!
//! 64 bytes covers x86-64 and mainstream aarch64 cores; on machines
//! with 128-byte prefetch pairs (Apple M-series) two values may still
//! prefetch together, which is the usual portable trade-off.

/// Pads and aligns `T` to a 64-byte cache line.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T>(pub T);

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        Self(value)
    }

    pub fn into_inner(self) -> T {
        self.0
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    #[inline]
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn occupies_a_full_line() {
        assert_eq!(std::mem::size_of::<CachePadded<AtomicU64>>(), 64);
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), 64);
        // Arrays of padded values put each element on its own line.
        let v: Vec<CachePadded<AtomicU64>> = (0..4).map(|_| CachePadded::default()).collect();
        let a = &*v[0] as *const AtomicU64 as usize;
        let b = &*v[1] as *const AtomicU64 as usize;
        assert!(b - a >= 64);
    }

    #[test]
    fn derefs_to_inner() {
        let c = CachePadded::new(AtomicU64::new(1));
        c.fetch_add(2, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 3);
        assert_eq!(CachePadded::new(7u32).into_inner(), 7);
    }
}
