//! Comparator schedulers the paper benchmarks against (Fig. 8: OmpSs;
//! Fig. 11: Gadget-2 — the latter lives in [`crate::nbody::baseline`]).
pub mod dep_only;

pub use dep_only::DepOnlyBuilder;
