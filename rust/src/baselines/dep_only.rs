//! Dependency-only baseline scheduler — the OmpSs/QUARK stand-in for
//! Fig. 8 (see DESIGN.md §Hardware-substitutions).
//!
//! Dependency-only runtimes differ from QuickSched in exactly the ways
//! §1/§2/§4.1 of the paper call out, and this module reproduces those
//! differences on top of the same executor so the comparison isolates
//! *scheduling policy*:
//!
//! 1. **Conflicts become dependencies**: two tasks locking the same
//!    resource are ordered by creation order (the order an automatic
//!    dependency-extraction runtime would impose), serializing them even
//!    when either order would do.
//! 2. **No critical-path weights**: ready tasks run roughly in creation
//!    order (FIFO keys) — OmpSs does not exploit whole-graph knowledge.
//! 3. **No resource-affinity routing**: tasks are enqueued round-robin,
//!    not to the queue owning their data.
//!
//! The transform understands hierarchical resources: two tasks conflict
//! when one's locked resource is an ancestor-or-equal of the other's.

use std::collections::HashMap;

use crate::coordinator::{
    GraphBuilder, KeyPolicy, ResId, SchedConfig, Scheduler, TaskFlags, TaskHandle,
};

/// Builder that records tasks + locks and lowers conflicts to
/// dependencies at `finish()`. Mirrors the subset of the
/// [`Scheduler`] build API the two applications use.
pub struct DepOnlyBuilder {
    sched: Scheduler,
    /// Lock lists per task, in creation order.
    locks: Vec<(TaskHandle, Vec<ResId>)>,
    /// Resource parents (the builder shadows the hierarchy so it can
    /// expand ancestor conflicts).
    parents: Vec<Option<ResId>>,
}

impl DepOnlyBuilder {
    /// A scheduler configured the way a dependency-only runtime works:
    /// FIFO keys, no affinity (owners ignored because enqueue scoring
    /// never sees a positive owner), random stealing.
    pub fn new(nr_queues: usize, seed: u64) -> crate::coordinator::Result<Self> {
        Self::new_with_config(SchedConfig::new(nr_queues).with_seed(seed))
    }

    /// As [`Self::new`] but keeping caller-chosen config extras (e.g.
    /// timeline recording); the dependency-only policy fields are forced.
    pub fn new_with_config(mut cfg: SchedConfig) -> crate::coordinator::Result<Self> {
        cfg.flags.key_policy = KeyPolicy::Fifo;
        cfg.flags.reown = false;
        Ok(Self {
            sched: Scheduler::new(cfg)?,
            locks: Vec::new(),
            parents: Vec::new(),
        })
    }

    pub fn add_task(&mut self, type_id: u32, data: &[u8], cost: i64) -> TaskHandle {
        self.raw_task(type_id, TaskFlags::default(), data.to_vec(), cost)
    }

    pub fn add_resource(&mut self, parent: Option<ResId>) -> ResId {
        // Owner deliberately none: no affinity routing.
        let r = self.sched.add_resource(parent, crate::coordinator::OWNER_NONE);
        self.parents.push(parent);
        r
    }

    /// Record a would-be lock; lowered to ordering dependencies later.
    pub fn add_lock(&mut self, t: TaskHandle, r: ResId) {
        let entry = self
            .locks
            .iter_mut()
            .rev()
            .find(|(h, _)| *h == t)
            .expect("unknown task");
        entry.1.push(r);
    }

    pub fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        self.sched.add_unlock(ta, tb);
    }

    /// Root-most ancestor chain of `r` (self first).
    fn ancestors(&self, mut r: ResId) -> Vec<ResId> {
        let mut out = vec![r];
        while let Some(p) = self.parents[r.idx()] {
            out.push(p);
            r = p;
        }
        out
    }

    /// Lower conflicts to dependencies and return the prepared scheduler.
    ///
    /// For each resource *node* (including ancestors of locked
    /// resources), tasks touching it are chained in creation order —
    /// the serialization an access-order-preserving runtime (OmpSs,
    /// QUARK without `CONCURRENT`) generates for inout parameters.
    pub fn finish(mut self) -> crate::coordinator::Result<Scheduler> {
        // last_task[node] = most recent task that touched `node`.
        let mut last_task: HashMap<ResId, TaskHandle> = HashMap::new();
        let lock_lists = std::mem::take(&mut self.locks);
        for (t, locks) in &lock_lists {
            // Expand each lock to itself + all ancestors (a lock on a
            // child conflicts with a lock on any ancestor).
            let mut nodes: Vec<ResId> = locks
                .iter()
                .flat_map(|&r| self.ancestors(r))
                .collect();
            nodes.sort_unstable();
            nodes.dedup();
            for node in nodes {
                if let Some(&prev) = last_task.get(&node) {
                    if prev != *t {
                        self.sched.add_unlock(prev, *t);
                    }
                }
                last_task.insert(node, *t);
            }
        }
        self.sched.prepare()?;
        Ok(self.sched)
    }
}

/// The baseline consumes the same application graph generators as the
/// real scheduler (resource owners are discarded — no affinity routing
/// in dependency-only runtimes; `uses` pass through harmlessly).
impl GraphBuilder for DepOnlyBuilder {
    fn raw_task(&mut self, type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> TaskHandle {
        let t = self.sched.push_task(type_id, flags, data, cost);
        self.locks.push((t, Vec::new()));
        t
    }

    fn add_resource(&mut self, parent: Option<ResId>, _owner: i32) -> ResId {
        DepOnlyBuilder::add_resource(self, parent)
    }

    fn add_lock(&mut self, t: TaskHandle, r: ResId) {
        DepOnlyBuilder::add_lock(self, t, r)
    }

    fn add_use(&mut self, _t: TaskHandle, _r: ResId) {
        // uses are affinity hints only; the baseline has no affinity.
    }

    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        DepOnlyBuilder::add_unlock(self, ta, tb)
    }

    fn nr_queues(&self) -> usize {
        self.sched.nr_queues()
    }

    fn nr_tasks_built(&self) -> usize {
        self.sched.nr_tasks()
    }

    fn nr_resources_built(&self) -> usize {
        self.parents.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::UnitCost;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn conflicts_become_chains() {
        let mut b = DepOnlyBuilder::new(2, 1).unwrap();
        let r = b.add_resource(None);
        for _ in 0..3 {
            b.task(0).cost(10).lock(r).spawn();
        }
        let mut s = b.finish().unwrap();
        // Chain: t0 → t1 → t2 ⇒ serial in creation order even on many
        // cores. (Same elapsed as a 1-core run.)
        let m2 = s.run_sim(4, &UnitCost).unwrap();
        assert_eq!(m2.tasks_run, 3);
        assert!(m2.elapsed_ns >= 30, "chained tasks must serialize");
    }

    #[test]
    fn hierarchical_conflicts_expand() {
        let mut b = DepOnlyBuilder::new(1, 1).unwrap();
        let root = b.add_resource(None);
        let child = b.add_resource(Some(root));
        b.task(0).lock(child).spawn();
        b.task(0).lock(root).spawn();
        let s = b.finish().unwrap();
        // t_root must depend on t_child (both touch node `root`).
        let stats = s.stats();
        assert_eq!(stats.dependencies, 1);
    }

    #[test]
    fn non_conflicting_tasks_stay_parallel() {
        let mut b = DepOnlyBuilder::new(4, 1).unwrap();
        for _ in 0..8 {
            let r = b.add_resource(None);
            b.task(0).cost(100).lock(r).spawn();
        }
        struct NoOverhead;
        impl crate::coordinator::CostModel for NoOverhead {
            fn duration_ns(
                &self,
                view: crate::coordinator::TaskView<'_>,
                _: &crate::coordinator::SimCtx,
            ) -> u64 {
                view.cost.max(1) as u64
            }
            fn gettask_overhead_ns(
                &self,
                _: crate::coordinator::TaskView<'_>,
                _: bool,
            ) -> u64 {
                0
            }
        }
        let mut s = b.finish().unwrap();
        assert_eq!(s.stats().dependencies, 0);
        let m = s.run_sim(4, &NoOverhead).unwrap();
        assert!(m.elapsed_ns < 8 * 100, "independent tasks must overlap");
    }

    #[test]
    fn quicksched_beats_dep_only_under_conflicts() {
        // The paper's core claim: conflicts-as-locks allow any order,
        // conflicts-as-dependencies impose one. Workload: K resources,
        // each with a burst of conflicting tasks, arriving interleaved.
        // QuickSched can run one task per resource concurrently;
        // dep-only's creation-order chains do the same here, BUT the
        // forced order prevents reordering around the stragglers when
        // costs vary. Use heterogeneous costs to expose it.
        let nq = 8;
        let k = 8;
        let bursts = 16;
        // --- QuickSched (locks) ---
        let mut s = Scheduler::new(SchedConfig::new(nq).with_seed(3)).unwrap();
        let rs: Vec<ResId> = (0..k)
            .map(|_| s.add_resource(None, crate::coordinator::OWNER_NONE))
            .collect();
        for b_i in 0..bursts {
            for (j, &r) in rs.iter().enumerate() {
                s.task(0)
                    .cost(10 + ((b_i * 7 + j * 13) % 90) as i64)
                    .lock(r)
                    .spawn();
            }
        }
        s.prepare().unwrap();
        let t_qs = s.run_sim(nq, &UnitCost).unwrap().elapsed_ns;
        // --- Dep-only ---
        let mut b = DepOnlyBuilder::new(nq, 3).unwrap();
        let rs: Vec<ResId> = (0..k).map(|_| b.add_resource(None)).collect();
        for b_i in 0..bursts {
            for (j, &r) in rs.iter().enumerate() {
                b.task(0).cost(10 + ((b_i * 7 + j * 13) % 90) as i64).lock(r).spawn();
            }
        }
        let mut s2 = b.finish().unwrap();
        let t_dep = s2.run_sim(nq, &UnitCost).unwrap().elapsed_ns;
        assert!(
            t_qs <= t_dep,
            "QuickSched ({t_qs}) must not lose to dep-only ({t_dep})"
        );
    }

    #[test]
    fn executes_everything_exactly_once() {
        let mut b = DepOnlyBuilder::new(2, 5).unwrap();
        let r = b.add_resource(None);
        for i in 0..20 {
            let mut spec = b.task(0).cost(1 + i);
            if i % 3 == 0 {
                spec = spec.lock(r);
            }
            spec.spawn();
        }
        let mut s = b.finish().unwrap();
        let count = AtomicU64::new(0);
        s.run(2, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 20);
    }
}
