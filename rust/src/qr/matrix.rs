//! Tiled matrix storage for the QR substrate (paper §4.1).
//!
//! The matrix is stored as `mt × nt` tiles of `b × b` doubles, each tile
//! row-major and contiguous — the layout Buttari et al. (2009) use to make
//! each kernel's working set cache-resident. Tiles are indexed
//! column-major (`i + j*mt`), matching the paper's `rid[j*m + i]`.
//!
//! During a parallel run, tiles are mutated under scheduler-enforced
//! exclusivity (locks + dependency chains), hence [`SharedGrid`].

use crate::util::rng::Rng;
use crate::util::shared::SharedGrid;

/// An `mt × nt` grid of `b × b` f64 tiles.
pub struct TiledMatrix {
    /// Tile edge length.
    pub b: usize,
    /// Tile rows.
    pub mt: usize,
    /// Tile columns.
    pub nt: usize,
    tiles: SharedGrid<Vec<f64>>,
    /// Householder tau vectors for the diagonal (GEQRF) factorizations,
    /// one `b`-vector per level k.
    taus_diag: SharedGrid<Vec<f64>>,
    /// tau vectors for the TSQRT factorizations, one per (i, k) tile.
    taus_ts: SharedGrid<Vec<f64>>,
}

impl TiledMatrix {
    pub fn zeros(b: usize, mt: usize, nt: usize) -> Self {
        assert!(b > 0 && mt > 0 && nt > 0);
        Self {
            b,
            mt,
            nt,
            tiles: SharedGrid::from_vec(
                (0..mt * nt).map(|_| vec![0.0; b * b]).collect(),
            ),
            taus_diag: SharedGrid::from_vec(
                (0..mt.min(nt)).map(|_| vec![0.0; b]).collect(),
            ),
            taus_ts: SharedGrid::from_vec((0..mt * nt).map(|_| vec![0.0; b]).collect()),
        }
    }

    /// Matrix with iid uniform [-1, 1) entries (the paper's random matrix).
    pub fn random(b: usize, mt: usize, nt: usize, seed: u64) -> Self {
        let m = Self::zeros(b, mt, nt);
        let mut rng = Rng::new(seed);
        for j in 0..nt {
            for i in 0..mt {
                // SAFETY: construction is single-threaded.
                let t = unsafe { m.tiles.get_mut(i + j * mt) };
                for x in t.iter_mut() {
                    *x = rng.range_f64(-1.0, 1.0);
                }
            }
        }
        m
    }

    /// Build from a dense row-major `(mt*b) × (nt*b)` matrix.
    pub fn from_dense(b: usize, mt: usize, nt: usize, dense: &[f64]) -> Self {
        let cols = nt * b;
        assert_eq!(dense.len(), mt * b * cols);
        let m = Self::zeros(b, mt, nt);
        for ti in 0..mt {
            for tj in 0..nt {
                let t = unsafe { m.tiles.get_mut(ti + tj * mt) };
                for r in 0..b {
                    for c in 0..b {
                        t[r * b + c] = dense[(ti * b + r) * cols + tj * b + c];
                    }
                }
            }
        }
        m
    }

    /// Flatten back to a dense row-major matrix.
    pub fn to_dense(&self) -> Vec<f64> {
        let (b, mt, nt) = (self.b, self.mt, self.nt);
        let cols = nt * b;
        let mut dense = vec![0.0; mt * b * cols];
        for ti in 0..mt {
            for tj in 0..nt {
                // SAFETY: caller holds &self outside any parallel run.
                let t = unsafe { self.tiles.get(ti + tj * mt) };
                for r in 0..b {
                    for c in 0..b {
                        dense[(ti * b + r) * cols + tj * b + c] = t[r * b + c];
                    }
                }
            }
        }
        dense
    }

    #[inline]
    pub fn tile_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.mt && j < self.nt);
        i + j * self.mt
    }

    /// Raw tile access under scheduler-enforced exclusivity.
    ///
    /// # Safety
    /// No other thread may access tile `(i, j)` concurrently (writes) —
    /// guaranteed by the QR task graph's locks and dependency chains.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn tile_mut(&self, i: usize, j: usize) -> &mut [f64] {
        self.tiles.get_mut(self.tile_index(i, j)).as_mut_slice()
    }

    /// # Safety
    /// No other thread may *write* tile `(i, j)` concurrently.
    pub unsafe fn tile(&self, i: usize, j: usize) -> &[f64] {
        self.tiles.get(self.tile_index(i, j)).as_slice()
    }

    /// # Safety
    /// As [`Self::tile_mut`], for the level-`k` diagonal tau vector.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn tau_diag_mut(&self, k: usize) -> &mut [f64] {
        self.taus_diag.get_mut(k).as_mut_slice()
    }

    /// # Safety
    /// As [`Self::tile`].
    pub unsafe fn tau_diag(&self, k: usize) -> &[f64] {
        self.taus_diag.get(k).as_slice()
    }

    /// # Safety
    /// As [`Self::tile_mut`], for the (i,k) TSQRT tau vector.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn tau_ts_mut(&self, i: usize, k: usize) -> &mut [f64] {
        self.taus_ts.get_mut(self.tile_index(i, k)).as_mut_slice()
    }

    /// # Safety
    /// As [`Self::tile`].
    pub unsafe fn tau_ts(&self, i: usize, k: usize) -> &[f64] {
        self.taus_ts.get(self.tile_index(i, k)).as_slice()
    }

    /// Extract the upper-triangular factor R (dense row-major, full size).
    /// Below-diagonal tiles hold Householder vectors, not zeros, so R is
    /// read from the upper-triangular part only.
    pub fn extract_r(&self) -> Vec<f64> {
        let (b, mt, nt) = (self.b, self.mt, self.nt);
        let rows = mt * b;
        let cols = nt * b;
        let dense = self.to_dense();
        let mut r = vec![0.0; rows * cols];
        for row in 0..rows.min(cols) {
            for col in row..cols {
                r[row * cols + col] = dense[row * cols + col];
            }
        }
        r
    }
}

/// Frobenius norm of a dense matrix.
pub fn fro_norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// `C = Aᵀ A` for a dense row-major `rows × cols` A (returns cols × cols).
pub fn gram(a: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(a.len(), rows * cols);
    let mut g = vec![0.0; cols * cols];
    for i in 0..cols {
        for j in i..cols {
            let mut s = 0.0;
            for r in 0..rows {
                s += a[r * cols + i] * a[r * cols + j];
            }
            g[i * cols + j] = s;
            g[j * cols + i] = s;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let b = 3;
        let (mt, nt) = (2, 2);
        let dense: Vec<f64> = (0..(mt * b) * (nt * b)).map(|x| x as f64).collect();
        let m = TiledMatrix::from_dense(b, mt, nt, &dense);
        assert_eq!(m.to_dense(), dense);
    }

    #[test]
    fn tile_indexing_column_major() {
        let m = TiledMatrix::zeros(2, 3, 2);
        assert_eq!(m.tile_index(0, 0), 0);
        assert_eq!(m.tile_index(2, 0), 2);
        assert_eq!(m.tile_index(0, 1), 3);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = TiledMatrix::random(4, 2, 2, 42).to_dense();
        let b = TiledMatrix::random(4, 2, 2, 42).to_dense();
        assert_eq!(a, b);
        assert!(a.iter().all(|x| (-1.0..1.0).contains(x)));
        let c = TiledMatrix::random(4, 2, 2, 43).to_dense();
        assert_ne!(a, c);
    }

    #[test]
    fn extract_r_upper_triangular() {
        let b = 2;
        let dense: Vec<f64> = (1..=16).map(|x| x as f64).collect();
        let m = TiledMatrix::from_dense(b, 2, 2, &dense);
        let r = m.extract_r();
        for row in 0..4 {
            for col in 0..4 {
                if col < row {
                    assert_eq!(r[row * 4 + col], 0.0);
                } else {
                    assert_eq!(r[row * 4 + col], dense[row * 4 + col]);
                }
            }
        }
    }

    #[test]
    fn gram_symmetric() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2
        let g = gram(&a, 3, 2);
        assert_eq!(g.len(), 4);
        assert!((g[0] - 35.0).abs() < 1e-12); // 1+9+25
        assert!((g[3] - 56.0).abs() < 1e-12); // 4+16+36
        assert_eq!(g[1], g[2]);
        assert!((g[1] - 44.0).abs() < 1e-12); // 2+12+30
    }

    #[test]
    fn fro_norm_basic() {
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
