//! Native tile kernels for the tiled QR decomposition (paper §4.1,
//! Buttari et al. 2009): GEQRF, LARFT-apply, TSQRT and SSRFT, operating
//! on `b × b` row-major f64 tiles.
//!
//! These are the rust twins of the Pallas kernels in
//! `python/compile/kernels/qr.py`; `python/tests/` checks the Pallas
//! versions against the same math, and `rust/tests/xla_backend.rs`
//! cross-checks the AOT-compiled HLO against these natives.
//!
//! Math: classic LAPACK-style Householder reflections,
//! `H = I − τ v vᵀ` with `v[k] = 1` stored implicitly and the tail of
//! `v` stored below the diagonal (GEQRF) or in the stacked tile (TSQRT).

/// Householder QR of a single `b × b` tile, in place (LAPACK `dgeqr2`).
/// On exit: R in the upper triangle, Householder vectors below the
/// diagonal, `tau[k]` per reflector.
pub fn geqrf(a: &mut [f64], tau: &mut [f64], b: usize) {
    debug_assert_eq!(a.len(), b * b);
    debug_assert_eq!(tau.len(), b);
    for k in 0..b {
        // Householder vector for column k, rows k..b.
        let mut nrm2 = 0.0;
        for i in k + 1..b {
            nrm2 += a[i * b + k] * a[i * b + k];
        }
        let alpha = a[k * b + k];
        let norm = (alpha * alpha + nrm2).sqrt();
        if nrm2 == 0.0 {
            // Column already zero below the diagonal: no reflection
            // (LAPACK dlarfg convention: tau = 0, beta = alpha).
            tau[k] = 0.0;
            continue;
        }
        let beta = if alpha >= 0.0 { -norm } else { norm };
        tau[k] = (beta - alpha) / beta;
        let scale = 1.0 / (alpha - beta);
        for i in k + 1..b {
            a[i * b + k] *= scale;
        }
        a[k * b + k] = beta;
        // Apply H_k to the trailing columns.
        for j in k + 1..b {
            let mut w = a[k * b + j];
            for i in k + 1..b {
                w += a[i * b + k] * a[i * b + j];
            }
            w *= tau[k];
            a[k * b + j] -= w;
            for i in k + 1..b {
                a[i * b + j] -= w * a[i * b + k];
            }
        }
    }
}

/// Apply `Qᵀ` from a GEQRF'd diagonal tile `v` (vectors below the
/// diagonal) to another tile `c` in the same block row (the paper's
/// DLARFT task; LAPACK `dormqr`-left-transpose, unblocked).
pub fn larft_apply(v: &[f64], tau: &[f64], c: &mut [f64], b: usize) {
    debug_assert_eq!(v.len(), b * b);
    debug_assert_eq!(c.len(), b * b);
    for k in 0..b {
        if tau[k] == 0.0 {
            continue;
        }
        for j in 0..b {
            let mut w = c[k * b + j];
            for i in k + 1..b {
                w += v[i * b + k] * c[i * b + j];
            }
            w *= tau[k];
            c[k * b + j] -= w;
            for i in k + 1..b {
                c[i * b + j] -= w * v[i * b + k];
            }
        }
    }
}

/// QR of the `2b × b` stack `[R; A]` where `R` (the level-k diagonal
/// tile) is upper triangular (the paper's DTSQRF task; PLASMA `dtsqrt`).
/// On exit: updated `R`; `A` holds the dense part of the Householder
/// vectors (`v = [e_k; A[:,k]]`), `tau[k]` per reflector.
pub fn tsqrt(r: &mut [f64], a: &mut [f64], tau: &mut [f64], b: usize) {
    debug_assert_eq!(r.len(), b * b);
    debug_assert_eq!(a.len(), b * b);
    debug_assert_eq!(tau.len(), b);
    for k in 0..b {
        // Column k spans r[k,k] (top) and a[0..b, k] (bottom); rows k+1..b
        // of the top part are zero (R upper triangular) and stay zero.
        let mut nrm2 = 0.0;
        for i in 0..b {
            nrm2 += a[i * b + k] * a[i * b + k];
        }
        let alpha = r[k * b + k];
        let norm = (alpha * alpha + nrm2).sqrt();
        if nrm2 == 0.0 {
            tau[k] = 0.0;
            continue;
        }
        let beta = if alpha >= 0.0 { -norm } else { norm };
        tau[k] = (beta - alpha) / beta;
        let scale = 1.0 / (alpha - beta);
        for i in 0..b {
            a[i * b + k] *= scale;
        }
        r[k * b + k] = beta;
        // Apply to trailing columns of the stack.
        for j in k + 1..b {
            let mut w = r[k * b + j];
            for i in 0..b {
                w += a[i * b + k] * a[i * b + j];
            }
            w *= tau[k];
            r[k * b + j] -= w;
            for i in 0..b {
                a[i * b + j] -= w * a[i * b + k];
            }
        }
    }
}

/// Apply the TSQRT reflectors (dense parts in `v2`, from tile `(i,k)`)
/// to the stacked pair `[c_kj; c_ij]` (the paper's DSSRFT task; PLASMA
/// `dtsssrf`/`dssrfb` unblocked).
pub fn ssrft(v2: &[f64], tau: &[f64], c_kj: &mut [f64], c_ij: &mut [f64], b: usize) {
    debug_assert_eq!(v2.len(), b * b);
    debug_assert_eq!(c_kj.len(), b * b);
    debug_assert_eq!(c_ij.len(), b * b);
    for k in 0..b {
        if tau[k] == 0.0 {
            continue;
        }
        for j in 0..b {
            // v = [e_k; v2[:,k]] so vᵀ[c_kj; c_ij] = c_kj[k,:] + v2ᵀ c_ij.
            let mut w = c_kj[k * b + j];
            for i in 0..b {
                w += v2[i * b + k] * c_ij[i * b + j];
            }
            w *= tau[k];
            c_kj[k * b + j] -= w;
            for i in 0..b {
                c_ij[i * b + j] -= w * v2[i * b + k];
            }
        }
    }
}

/// Asymptotic relative costs of the four kernels in units of `b³` flops
/// (used as the paper's a-priori task costs; §4.1 "task costs were
/// initialized to the asymptotic cost of the underlying operations").
pub mod cost {
    /// GEQRF ~ (4/3) b³.
    pub const GEQRF: i64 = 4;
    /// LARFT apply ~ 2 b³ per target tile... relative units ×3.
    pub const LARFT: i64 = 6;
    /// TSQRT ~ 2 b³ (structured stack).
    pub const TSQRT: i64 = 6;
    /// SSRFT ~ 4 b³ (two tiles updated per reflector).
    pub const SSRFT: i64 = 12;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tile(b: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..b * b).map(|_| rng.range_f64(-1.0, 1.0)).collect()
    }

    /// Dense reference QR via Householder on an `m × n` row-major matrix;
    /// returns (v_and_r_packed, taus) exactly like geqrf but rectangular.
    fn ref_geqrf(a: &mut [f64], m: usize, n: usize) -> Vec<f64> {
        let mut tau = vec![0.0; n.min(m)];
        for k in 0..n.min(m) {
            let mut nrm2 = 0.0;
            for i in k + 1..m {
                nrm2 += a[i * n + k] * a[i * n + k];
            }
            let alpha = a[k * n + k];
            let norm = (alpha * alpha + nrm2).sqrt();
            if nrm2 == 0.0 {
                continue;
            }
            let beta = if alpha >= 0.0 { -norm } else { norm };
            tau[k] = (beta - alpha) / beta;
            let scale = 1.0 / (alpha - beta);
            for i in k + 1..m {
                a[i * n + k] *= scale;
            }
            a[k * n + k] = beta;
            for j in k + 1..n {
                let mut w = a[k * n + j];
                for i in k + 1..m {
                    w += a[i * n + k] * a[i * n + j];
                }
                w *= tau[k];
                a[k * n + j] -= w;
                for i in k + 1..m {
                    a[i * n + j] -= w * a[i * n + k];
                }
            }
        }
        tau
    }

    fn upper_abs(a: &[f64], m: usize, n: usize) -> Vec<f64> {
        let mut r = vec![0.0; n * n];
        for i in 0..n.min(m) {
            for j in i..n {
                r[i * n + j] = a[i * n + j].abs();
            }
        }
        r
    }

    fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
        assert_eq!(a.len(), b.len());
        let scale = b.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * scale,
                "{what}: idx {i}: {x} vs {y} (tol {tol})"
            );
        }
    }

    #[test]
    fn geqrf_reproduces_r_of_reference() {
        for b in [1usize, 2, 3, 5, 8, 16] {
            let mut a = rand_tile(b, 100 + b as u64);
            let a0 = a.clone();
            let mut tau = vec![0.0; b];
            geqrf(&mut a, &mut tau, b);
            let mut aref = a0.clone();
            ref_geqrf(&mut aref, b, b);
            assert_close(
                &upper_abs(&a, b, b),
                &upper_abs(&aref, b, b),
                1e-12,
                &format!("R mismatch b={b}"),
            );
        }
    }

    #[test]
    fn geqrf_preserves_gram() {
        // AᵀA == RᵀR since Q is orthogonal.
        let b = 8;
        let a0 = rand_tile(b, 7);
        let mut a = a0.clone();
        let mut tau = vec![0.0; b];
        geqrf(&mut a, &mut tau, b);
        let g0 = crate::qr::matrix::gram(&a0, b, b);
        let r = upper_of(&a, b);
        let gr = crate::qr::matrix::gram(&r, b, b);
        assert_close(&gr, &g0, 1e-12, "gram");
    }

    fn upper_of(a: &[f64], b: usize) -> Vec<f64> {
        let mut r = vec![0.0; b * b];
        for i in 0..b {
            for j in i..b {
                r[i * b + j] = a[i * b + j];
            }
        }
        r
    }

    #[test]
    fn geqrf_zero_matrix() {
        let b = 4;
        let mut a = vec![0.0; b * b];
        let mut tau = vec![0.0; b];
        geqrf(&mut a, &mut tau, b);
        assert!(a.iter().all(|&x| x == 0.0));
        assert!(tau.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn geqrf_identity_noop() {
        let b = 4;
        let mut a = vec![0.0; b * b];
        for i in 0..b {
            a[i * b + i] = 1.0;
        }
        let before = a.clone();
        let mut tau = vec![0.0; b];
        geqrf(&mut a, &mut tau, b);
        assert_close(&a, &before, 1e-15, "identity should be a fixpoint");
    }

    #[test]
    fn larft_apply_matches_full_factorization() {
        // QR of [A | C] (b × 2b): factor with ref_geqrf; the right half
        // after factoring must equal larft_apply(V from geqrf(A)) to C.
        let b = 6;
        let a0 = rand_tile(b, 21);
        let c0 = rand_tile(b, 22);
        // Full reference on b × 2b.
        let n = 2 * b;
        let mut full = vec![0.0; b * n];
        for i in 0..b {
            for j in 0..b {
                full[i * n + j] = a0[i * b + j];
                full[i * n + b + j] = c0[i * b + j];
            }
        }
        ref_geqrf(&mut full, b, n);
        // Tiled path.
        let mut v = a0.clone();
        let mut tau = vec![0.0; b];
        geqrf(&mut v, &mut tau, b);
        let mut c = c0.clone();
        larft_apply(&v, &tau, &mut c, b);
        let full_ref = &full;
        let right_ref: Vec<f64> = (0..b)
            .flat_map(|i| (0..b).map(move |j| full_ref[i * n + b + j]))
            .collect();
        assert_close(&c, &right_ref, 1e-12, "DLARFT");
    }

    #[test]
    fn tsqrt_gram_preserved() {
        // [R0; A] where R0 = R of geqrf(top): gram of the 2b × b stack
        // must equal RᵀR of the tsqrt result.
        let b = 5;
        let mut top = rand_tile(b, 31);
        let mut tau0 = vec![0.0; b];
        geqrf(&mut top, &mut tau0, b);
        let r0 = upper_of(&top, b);
        let a0 = rand_tile(b, 32);
        let mut stack = vec![0.0; 2 * b * b];
        stack[..b * b].copy_from_slice(&r0);
        stack[b * b..].copy_from_slice(&a0);
        let g0 = crate::qr::matrix::gram(&stack, 2 * b, b);

        let mut r = r0.clone();
        let mut a = a0.clone();
        let mut tau = vec![0.0; b];
        tsqrt(&mut r, &mut a, &mut tau, b);
        let r_up = upper_of(&r, b);
        let gr = crate::qr::matrix::gram(&r_up, b, b);
        assert_close(&gr, &g0, 1e-12, "tsqrt gram");
        // R must match the reference QR of the stack up to row signs.
        let mut stack_ref = stack.clone();
        ref_geqrf(&mut stack_ref, 2 * b, b);
        assert_close(
            &upper_abs(&r, b, b),
            &upper_abs(&stack_ref, 2 * b, b),
            1e-12,
            "tsqrt |R|",
        );
    }

    #[test]
    fn ssrft_matches_full_factorization() {
        // Factor the 2b × 2b stack [[A, B], [C, D]] where the left column
        // is eliminated via geqrf(A) then tsqrt(R, C). Applying the same
        // transforms to [B; D] via larft_apply + ssrft must reproduce the
        // reference QR of the full 2b × 2b matrix (up to signs on R).
        let b = 4;
        let a0 = rand_tile(b, 41);
        let b0 = rand_tile(b, 42);
        let c0 = rand_tile(b, 43);
        let d0 = rand_tile(b, 44);
        let n = 2 * b;
        let mut full = vec![0.0; n * n];
        for i in 0..b {
            for j in 0..b {
                full[i * n + j] = a0[i * b + j];
                full[i * n + b + j] = b0[i * b + j];
                full[(b + i) * n + j] = c0[i * b + j];
                full[(b + i) * n + b + j] = d0[i * b + j];
            }
        }
        let g_full = crate::qr::matrix::gram(&full, n, n);

        // Tiled elimination of the first tile column.
        let mut v = a0.clone();
        let mut tau_g = vec![0.0; b];
        geqrf(&mut v, &mut tau_g, b);
        let mut bk = b0.clone();
        larft_apply(&v, &tau_g, &mut bk, b);
        let mut r = upper_of(&v, b);
        let mut v2 = c0.clone();
        let mut tau_t = vec![0.0; b];
        tsqrt(&mut r, &mut v2, &mut tau_t, b);
        let mut ckj = bk.clone();
        let mut cij = d0.clone();
        ssrft(&v2, &tau_t, &mut ckj, &mut cij, b);

        // Second tile column: geqrf on the updated D block.
        let mut v_d = cij.clone();
        let mut tau_d = vec![0.0; b];
        geqrf(&mut v_d, &mut tau_d, b);

        // Assemble tiled R and compare grams (orthogonal invariance).
        let mut r_tiled = vec![0.0; n * n];
        for i in 0..b {
            for j in 0..b {
                if j >= i {
                    r_tiled[i * n + j] = r[i * b + j];
                    r_tiled[(b + i) * n + b + j] = if j >= i { v_d[i * b + j] } else { 0.0 };
                }
                r_tiled[i * n + b + j] = ckj[i * b + j];
            }
        }
        // zero below diag within D tile handled above; compute gram.
        let g_tiled = crate::qr::matrix::gram(&r_tiled, n, n);
        assert_close(&g_tiled, &g_full, 1e-11, "2x2-tile gram");
    }

    #[test]
    fn costs_are_ordered() {
        assert!(cost::GEQRF < cost::SSRFT);
        assert!(cost::LARFT <= cost::TSQRT);
    }
}
