//! Correctness oracles for the tiled QR factorization.
//!
//! The main invariant: a QR factorization preserves the Gram matrix,
//! `AᵀA = RᵀR` (Q orthogonal). Checking this needs neither an explicit
//! Q nor a reference LAPACK — it is exact up to rounding and catches
//! any wrong update in any kernel. We additionally check `R` is upper
//! triangular by construction and compare `|R|` against an independent
//! full-matrix Householder QR on small problems.

use super::matrix::{fro_norm, gram, TiledMatrix};

/// ‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F for the factorized `mat` vs the original
/// dense `a0`. Values ≲ 1e-12 indicate a correct factorization in f64.
pub fn gram_residual(a0: &[f64], mat: &TiledMatrix) -> f64 {
    let rows = mat.mt * mat.b;
    let cols = mat.nt * mat.b;
    assert_eq!(a0.len(), rows * cols);
    let r = mat.extract_r();
    let g0 = gram(a0, rows, cols);
    let gr = gram(&r, rows, cols);
    let diff: Vec<f64> = g0.iter().zip(&gr).map(|(x, y)| x - y).collect();
    fro_norm(&diff) / fro_norm(&g0)
}

/// Reference full-matrix Householder QR returning `|R|` (row signs are
/// not unique across algorithms, absolute values are, for full-rank A).
pub fn reference_abs_r(a0: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    let mut a = a0.to_vec();
    for k in 0..cols.min(rows) {
        let mut nrm2 = 0.0;
        for i in k + 1..rows {
            nrm2 += a[i * cols + k] * a[i * cols + k];
        }
        let alpha = a[k * cols + k];
        let norm = (alpha * alpha + nrm2).sqrt();
        if nrm2 == 0.0 {
            // LAPACK dlarfg convention: tau = 0, no reflection.
            continue;
        }
        let beta = if alpha >= 0.0 { -norm } else { norm };
        let tau = (beta - alpha) / beta;
        let scale = 1.0 / (alpha - beta);
        for i in k + 1..rows {
            a[i * cols + k] *= scale;
        }
        a[k * cols + k] = beta;
        for j in k + 1..cols {
            let mut w = a[k * cols + j];
            for i in k + 1..rows {
                w += a[i * cols + k] * a[i * cols + j];
            }
            w *= tau;
            a[k * cols + j] -= w;
            for i in k + 1..rows {
                a[i * cols + j] -= w * a[i * cols + k];
            }
        }
    }
    let mut r = vec![0.0; rows * cols];
    for i in 0..rows.min(cols) {
        for j in i..cols {
            r[i * cols + j] = a[i * cols + j].abs();
        }
    }
    r
}

/// Max elementwise |R| deviation from the reference QR, scaled.
pub fn abs_r_deviation(a0: &[f64], mat: &TiledMatrix) -> f64 {
    let rows = mat.mt * mat.b;
    let cols = mat.nt * mat.b;
    let r_ref = reference_abs_r(a0, rows, cols);
    let r = mat.extract_r();
    let scale = r_ref.iter().fold(1.0f64, |m, x| m.max(*x));
    r.iter()
        .zip(&r_ref)
        .map(|(x, y)| (x.abs() - y).abs())
        .fold(0.0f64, f64::max)
        / scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SchedConfig;
    use crate::qr::driver::{run_threaded, NativeBackend};

    #[test]
    fn residual_zero_for_prefactored() {
        // A = upper triangular: QR is A itself (up to signs), residual 0.
        let b = 4;
        let mut dense = vec![0.0; 64];
        for i in 0..8 {
            for j in i..8 {
                dense[i * 8 + j] = (1 + i + j) as f64;
            }
        }
        let mat = TiledMatrix::from_dense(b, 2, 2, &dense);
        run_threaded(&mat, &NativeBackend, SchedConfig::new(1), 1).unwrap();
        assert!(gram_residual(&dense, &mat) < 1e-12);
        assert!(abs_r_deviation(&dense, &mat) < 1e-12);
    }

    #[test]
    fn both_oracles_agree_on_random() {
        for (mt, nt, b, seed) in [(2, 2, 4, 11u64), (3, 3, 8, 12), (4, 2, 4, 13)] {
            let mat = TiledMatrix::random(b, mt, nt, seed);
            let a0 = mat.to_dense();
            run_threaded(&mat, &NativeBackend, SchedConfig::new(2), 2).unwrap();
            let g = gram_residual(&a0, &mat);
            let d = abs_r_deviation(&a0, &mat);
            assert!(g < 1e-12, "gram residual {g} (mt={mt},nt={nt},b={b})");
            assert!(d < 1e-10, "abs-R deviation {d} (mt={mt},nt={nt},b={b})");
        }
    }

    #[test]
    fn oracle_detects_corruption() {
        let mat = TiledMatrix::random(4, 2, 2, 5);
        let a0 = mat.to_dense();
        run_threaded(&mat, &NativeBackend, SchedConfig::new(1), 1).unwrap();
        // Corrupt one R entry.
        unsafe {
            mat.tile_mut(0, 1)[3] += 0.5;
        }
        assert!(gram_residual(&a0, &mat) > 1e-6, "oracle must catch corruption");
    }
}
