//! Task-graph generation for the tiled QR decomposition (paper §4.1,
//! Appendix B, and Algorithm 2 of Buttari et al. 2009).
//!
//! For an `m × n` tile matrix, tasks are the tuples `(i, j, k)`:
//!
//! | task   | where        | depends on                          | locks        | uses   |
//! |--------|--------------|-------------------------------------|--------------|--------|
//! | GEQRF  | i = j = k    | (i,j,k-1)                           | (i,j)        |        |
//! | LARFT  | i = k, j > k | (i,j,k-1), (k,k,k)                  | (i,j)        | (k,k)  |
//! | TSQRT  | i > k, j = k | (i,j,k-1), (i-1,j,k)                | (i,j)        | (k,k)  |
//! | SSRFT  | i > k, j > k | (i,j,k-1), (i-1,j,k), (i,k,k)       | (i,j), (k,j) | (i,k)  |
//!
//! The lock/use split reproduces the paper's §4.1 counts exactly
//! (21 856 locks, 11 408 uses for 32 × 32 tiles): writes to the level-k
//! diagonal tile by TSQRT and to the `(k,j)` row tile by SSRFT are
//! serialized by the `(i-1,j,k)` dependency chain; SSRFT additionally
//! locks `(k,j)` and TSQRT relies on the chain alone. Dependency *edges*
//! follow the table, which is the correct serialization (the paper's
//! printed edge count corresponds to its Appendix-B variant that omits
//! one SSRFT edge class; see EXPERIMENTS.md §E1).

use crate::coordinator::{GraphBuilder, Payload, ResHandle, TaskHandle, TaskType};

/// QR task types, bound to kernels via the
/// [`crate::coordinator::KernelRegistry`] (see [`super::driver::registry`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum QrTask {
    Geqrf = 0,
    Larft = 1,
    Tsqrt = 2,
    Ssrft = 3,
}

impl QrTask {
    pub fn from_u32(x: u32) -> Self {
        match x {
            0 => Self::Geqrf,
            1 => Self::Larft,
            2 => Self::Tsqrt,
            3 => Self::Ssrft,
            _ => panic!("unknown QR task type {x}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Geqrf => "DGEQRF",
            Self::Larft => "DLARFT",
            Self::Tsqrt => "DTSQRF",
            Self::Ssrft => "DSSRFT",
        }
    }
}

impl TaskType for QrTask {
    fn type_id(self) -> u32 {
        self as u32
    }

    fn type_name(self) -> &'static str {
        self.name()
    }
}

/// Handles produced by [`build_tasks`].
pub struct QrGraph {
    /// Tile resources, column-major `i + j*m`.
    pub rid: Vec<ResHandle>,
    pub m: usize,
    pub n: usize,
}

/// Typed payload of a QR task: the `(i, j, k)` tile tuple.
fn enc(i: usize, j: usize, k: usize) -> (i32, i32, i32) {
    (i as i32, j as i32, k as i32)
}

/// Decode a QR task payload back into `(i, j, k)`.
pub fn decode(data: &[u8]) -> (usize, usize, usize) {
    let (i, j, k) = <(i32, i32, i32)>::decode(data);
    (i as usize, j as usize, k as usize)
}

/// Build the full task graph for an `m × n` tile matrix into `sched`.
///
/// Tile resources are created with owners assigned in column-major block
/// order over the queues (§4.1: "the first ⌊n_tiles/n_queues⌋ are
/// assigned to the first queue, and so on"). Costs are the asymptotic
/// kernel costs in units of b³ (see [`super::kernels::cost`]).
pub fn build_tasks<B: GraphBuilder>(sched: &mut B, m: usize, n: usize) -> QrGraph {
    let nq = sched.nr_queues();
    let ntiles = m * n;
    let per_q = ntiles.div_ceil(nq);
    let mut rid = Vec::with_capacity(ntiles);
    for t in 0..ntiles {
        let owner = (t / per_q).min(nq - 1) as i32;
        rid.push(sched.add_resource(None, owner));
    }
    // tid[j*m + i] = handle of the last task at tile (i, j), or None.
    let mut tid: Vec<Option<TaskHandle>> = vec![None; ntiles];
    let at = |i: usize, j: usize| j * m + i;
    use super::kernels::cost;

    for k in 0..m.min(n) {
        // GEQRF at (k, k); depends on the previous level at this tile.
        let t_kk = sched
            .task(QrTask::Geqrf)
            .payload(&enc(k, k, k))
            .cost(cost::GEQRF)
            .lock(rid[at(k, k)])
            .after(tid[at(k, k)])
            .spawn();
        tid[at(k, k)] = Some(t_kk);

        // LARFT along row k.
        for j in k + 1..n {
            let t = sched
                .task(QrTask::Larft)
                .payload(&enc(k, j, k))
                .cost(cost::LARFT)
                .lock(rid[at(k, j)])
                .use_res(rid[at(k, k)])
                .after([t_kk])
                .after(tid[at(k, j)])
                .spawn();
            tid[at(k, j)] = Some(t);
        }

        // TSQRT down column k, chained i-1 → i (serializes the (k,k)
        // R-tile updates). (i-1, k, k) is the previous TSQRT or the
        // GEQRF itself.
        for i in k + 1..m {
            let above = tid[at(i - 1, k)].expect("TSQRT chain predecessor");
            let t = sched
                .task(QrTask::Tsqrt)
                .payload(&enc(i, k, k))
                .cost(cost::TSQRT)
                .lock(rid[at(i, k)])
                .use_res(rid[at(k, k)])
                .after([above])
                .after(tid[at(i, k)])
                .spawn();
            tid[at(i, k)] = Some(t);

            // SSRFT along row i, for every column j > k: after
            // (i-1, j, k) — the previous SSRFT in the column or the
            // LARFT — plus (i, k, k) — the TSQRT that produced our V
            // tile — plus (i, j, k-1), the previous level at this tile.
            for j in k + 1..n {
                let above = tid[at(i - 1, j)].expect("SSRFT chain predecessor");
                let ts = sched
                    .task(QrTask::Ssrft)
                    .payload(&enc(i, j, k))
                    .cost(cost::SSRFT)
                    .locks([rid[at(i, j)], rid[at(k, j)]])
                    .use_res(rid[at(i, k)])
                    .after([above, t])
                    .after(tid[at(i, j)])
                    .spawn();
                tid[at(i, j)] = Some(ts);
            }
        }
        // tid tracks the latest task per tile, which is exactly the
        // table's (i-1, j, k) chain head for the next level.
    }
    QrGraph { rid, m, n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SchedConfig, Scheduler};

    fn build(m: usize, n: usize, nq: usize) -> (Scheduler, QrGraph) {
        let mut s = Scheduler::new(SchedConfig::new(nq)).unwrap();
        let g = build_tasks(&mut s, m, n);
        s.prepare().unwrap();
        (s, g)
    }

    /// Analytic counts for an N×N tile matrix.
    fn expected_counts(nn: usize) -> (usize, usize, usize) {
        // tasks: N geqrf + N(N-1)/2 larft + N(N-1)/2 tsqrt + sum k² ssrft
        let larft = nn * (nn - 1) / 2;
        let ssrft = (nn - 1) * nn * (2 * nn - 1) / 6;
        let tasks = nn + 2 * larft + ssrft;
        // locks: geqrf 1, larft 1, tsqrt 1, ssrft 2
        let locks = nn + larft + larft + 2 * ssrft;
        // uses: larft 1, tsqrt 1, ssrft 1
        let uses = 2 * larft + ssrft;
        (tasks, locks, uses)
    }

    #[test]
    fn paper_counts_32x32() {
        // §4.1: 2048×2048 with 64×64 tiles → 32×32 tiles; the paper
        // reports 11 440 tasks, 1 024 resources, 21 856 locks, 11 408
        // uses. (Dependency edges: see EXPERIMENTS.md §E1.)
        let (s, g) = build(32, 32, 4);
        let st = s.stats();
        assert_eq!(st.tasks, 11_440);
        assert_eq!(st.resources, 1_024);
        assert_eq!(st.locks, 21_856);
        assert_eq!(st.uses, 11_408);
        assert_eq!(g.rid.len(), 1024);
        let (t, l, u) = expected_counts(32);
        assert_eq!((st.tasks, st.locks, st.uses), (t, l, u));
    }

    #[test]
    fn small_graph_structure() {
        let (s, _) = build(2, 2, 1);
        let st = s.stats();
        // k=0: GEQRF(0,0), LARFT(0,1), TSQRT(1,0), SSRFT(1,1);
        // k=1: GEQRF(1,1). Total 5.
        assert_eq!(st.tasks, 5);
        assert_eq!(st.roots, 1, "only GEQRF(0,0,0) is initially ready");
        assert_eq!(st.resources, 4);
        let (t, l, u) = expected_counts(2);
        assert_eq!((st.tasks, st.locks, st.uses), (t, l, u));
    }

    #[test]
    fn rectangular_tall() {
        let (s, _) = build(4, 2, 2);
        // k in 0..2; tasks: k=0: 1 + 1 larft + 3 tsqrt + 3 ssrft = 8;
        // k=1: 1 + 0 + 2 tsqrt + 0 = 3. Total 11.
        assert_eq!(s.stats().tasks, 11);
        s.critical_path();
    }

    #[test]
    fn graph_is_acyclic_and_runs() {
        let (mut s, _) = build(4, 4, 2);
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        s.run(2, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), s.nr_tasks());
    }

    #[test]
    fn resource_owners_block_distributed() {
        let (s, g) = build(4, 4, 4);
        // 16 tiles over 4 queues → 4 tiles each, in column-major order.
        let owners: Vec<i32> = g.rid.iter().map(|&r| s.resources().get(r).owner()).collect();
        assert_eq!(owners[0], 0);
        assert_eq!(owners[4], 1);
        assert_eq!(owners[15], 3);
    }

    #[test]
    fn decode_roundtrip() {
        let p = enc(3, 7, 2).encode();
        assert_eq!(decode(&p), (3, 7, 2));
    }

    #[test]
    fn geqrf_tasks_on_critical_path() {
        // §4.1/Fig 9: the GEQRF tasks lie on the longest critical path —
        // their weight must be >= any same-level SSRFT weight.
        let (s, _) = build(8, 8, 1);
        let mut geqrf_w = Vec::new();
        let mut ssrft_w = Vec::new();
        for t in 0..s.nr_tasks() {
            let v = s.task_view(crate::coordinator::TaskId(t as u32));
            let (_, _, k) = decode(v.data);
            if k == 0 {
                match QrTask::from_u32(v.type_id) {
                    QrTask::Geqrf => geqrf_w.push(v.weight),
                    QrTask::Ssrft => ssrft_w.push(v.weight),
                    _ => {}
                }
            }
        }
        let min_geqrf = geqrf_w.iter().min().unwrap();
        let max_ssrft = ssrft_w.iter().max().unwrap();
        assert!(min_geqrf >= max_ssrft, "GEQRF {min_geqrf} vs SSRFT {max_ssrft}");
    }
}
