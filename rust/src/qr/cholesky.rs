//! Tiled Cholesky factorization — the first of the three PLASMA
//! algorithms of Buttari et al. (2009) that the paper's §4.1 builds on
//! (the paper benchmarks QR; Cholesky exercises the scheduler with a
//! sparser dependency structure and is included as the "more task types"
//! extension workload).
//!
//! For an SPD matrix of `N × N` tiles, level k:
//!
//! | task  | where            | depends on                    | locks |
//! |-------|------------------|-------------------------------|-------|
//! | POTRF | i = j = k        | SYRK(k,k,k-1)                 | (k,k) |
//! | TRSM  | i > k, j = k     | POTRF(k), GEMM(i,k,k-1)       | (i,k) |
//! | SYRK  | i = j > k        | TRSM(i,k), SYRK(i,i,k-1)      | (i,i) |
//! | GEMM  | i > j > k        | TRSM(i,k), TRSM(j,k), GEMM(i,j,k-1) | (i,j) |
//!
//! Kernels operate on the lower triangle; `L` ends up in the lower
//! triangular tiles. Verification: `‖A − L·Lᵀ‖_F / ‖A‖_F`.

use std::ops::Deref;

use crate::coordinator::{
    GraphBuilder, KernelRegistry, Payload, ResHandle, SchedConfig, TaskHandle, TaskType, TaskView,
};
use crate::util::rng::Rng;

use super::matrix::{fro_norm, TiledMatrix};

/// Cholesky task types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum CholTask {
    Potrf = 0,
    Trsm = 1,
    Syrk = 2,
    Gemm = 3,
}

impl CholTask {
    pub fn from_u32(x: u32) -> Self {
        match x {
            0 => Self::Potrf,
            1 => Self::Trsm,
            2 => Self::Syrk,
            3 => Self::Gemm,
            _ => panic!("unknown Cholesky task type {x}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Potrf => "DPOTRF",
            Self::Trsm => "DTRSM",
            Self::Syrk => "DSYRK",
            Self::Gemm => "DGEMM",
        }
    }
}

impl TaskType for CholTask {
    fn type_id(self) -> u32 {
        self as u32
    }

    fn type_name(self) -> &'static str {
        self.name()
    }
}

// ----------------------------------------------------------------------
// Native tile kernels (b × b row-major, f64)
// ----------------------------------------------------------------------

/// Unblocked Cholesky of one SPD tile: `A = L·Lᵀ`, L into the lower
/// triangle (upper left untouched). Panics on non-positive pivots.
pub fn potrf(a: &mut [f64], b: usize) {
    for k in 0..b {
        let mut d = a[k * b + k];
        for p in 0..k {
            d -= a[k * b + p] * a[k * b + p];
        }
        assert!(d > 0.0, "matrix not positive definite (pivot {k}: {d})");
        let d = d.sqrt();
        a[k * b + k] = d;
        for i in k + 1..b {
            let mut s = a[i * b + k];
            for p in 0..k {
                s -= a[i * b + p] * a[k * b + p];
            }
            a[i * b + k] = s / d;
        }
    }
}

/// Triangular solve: `B ← B · L⁻ᵀ` where `L` is the POTRF'd diagonal
/// tile (lower). Applied to the sub-diagonal tiles of the panel.
pub fn trsm(l: &[f64], b_tile: &mut [f64], b: usize) {
    for r in 0..b {
        for c in 0..b {
            let mut s = b_tile[r * b + c];
            for p in 0..c {
                s -= b_tile[r * b + p] * l[c * b + p];
            }
            b_tile[r * b + c] = s / l[c * b + c];
        }
    }
}

/// Symmetric rank-k update of a diagonal tile: `C ← C − A·Aᵀ` (lower
/// triangle only; upper is ignored by later kernels).
pub fn syrk(a: &[f64], c: &mut [f64], b: usize) {
    for r in 0..b {
        for col in 0..=r {
            let mut s = 0.0;
            for p in 0..b {
                s += a[r * b + p] * a[col * b + p];
            }
            c[r * b + col] -= s;
        }
    }
}

/// General update of an off-diagonal tile: `C ← C − A·Bᵀ`.
pub fn gemm_nt(a: &[f64], bt: &[f64], c: &mut [f64], b: usize) {
    for r in 0..b {
        for col in 0..b {
            let mut s = 0.0;
            for p in 0..b {
                s += a[r * b + p] * bt[col * b + p];
            }
            c[r * b + col] -= s;
        }
    }
}

/// Relative costs in b³ units.
pub mod cost {
    pub const POTRF: i64 = 1;
    pub const TRSM: i64 = 3;
    pub const SYRK: i64 = 3;
    pub const GEMM: i64 = 6;
}

// ----------------------------------------------------------------------
// Task graph
// ----------------------------------------------------------------------

pub struct CholGraph {
    pub rid: Vec<ResHandle>,
    pub n: usize,
}

fn enc(i: usize, j: usize, k: usize) -> (i32, i32, i32) {
    (i as i32, j as i32, k as i32)
}

pub fn decode(data: &[u8]) -> (usize, usize, usize) {
    let (i, j, k) = <(i32, i32, i32)>::decode(data);
    (i as usize, j as usize, k as usize)
}

/// Build the Cholesky task graph for an `n × n` tile matrix.
pub fn build_tasks<B: GraphBuilder>(sched: &mut B, n: usize) -> CholGraph {
    let nq = sched.nr_queues();
    let per_q = (n * n).div_ceil(nq);
    let rid: Vec<ResHandle> = (0..n * n)
        .map(|t| sched.add_resource(None, ((t / per_q).min(nq - 1)) as i32))
        .collect();
    let at = |i: usize, j: usize| j * n + i;
    // last task touching tile (i, j)
    let mut tid: Vec<Option<TaskHandle>> = vec![None; n * n];

    for k in 0..n {
        let t_potrf = sched
            .task(CholTask::Potrf)
            .payload(&enc(k, k, k))
            .cost(cost::POTRF)
            .lock(rid[at(k, k)])
            .after(tid[at(k, k)])
            .spawn();
        tid[at(k, k)] = Some(t_potrf);

        for i in k + 1..n {
            let t_trsm = sched
                .task(CholTask::Trsm)
                .payload(&enc(i, k, k))
                .cost(cost::TRSM)
                .lock(rid[at(i, k)])
                .use_res(rid[at(k, k)])
                .after([t_potrf])
                .after(tid[at(i, k)])
                .spawn();
            tid[at(i, k)] = Some(t_trsm);
        }
        for i in k + 1..n {
            let t_row_i = tid[at(i, k)].unwrap();
            // SYRK on the diagonal tile (i, i).
            let t_syrk = sched
                .task(CholTask::Syrk)
                .payload(&enc(i, i, k))
                .cost(cost::SYRK)
                .lock(rid[at(i, i)])
                .use_res(rid[at(i, k)])
                .after([t_row_i])
                .after(tid[at(i, i)])
                .spawn();
            tid[at(i, i)] = Some(t_syrk);
            // GEMMs below the diagonal: tile (i, j), k < j < i.
            for j in k + 1..i {
                let t_gemm = sched
                    .task(CholTask::Gemm)
                    .payload(&enc(i, j, k))
                    .cost(cost::GEMM)
                    .lock(rid[at(i, j)])
                    .uses([rid[at(i, k)], rid[at(j, k)]])
                    .after([t_row_i, tid[at(j, k)].unwrap()])
                    .after(tid[at(i, j)])
                    .spawn();
                tid[at(i, j)] = Some(t_gemm);
            }
        }
    }
    CholGraph { rid, n }
}

/// Bind the four Cholesky kernels against `mat` into a
/// [`KernelRegistry`] (cf. [`super::driver::registry`] for QR).
///
/// Safety: per the graph above — writes under locks, reads of panel
/// tiles ordered by dependencies.
pub fn registry<'a, M>(mat: M) -> KernelRegistry<'a>
where
    M: Deref<Target = TiledMatrix> + Clone + Send + Sync + 'a,
{
    let m1 = mat.clone();
    let m2 = mat.clone();
    let m3 = mat.clone();
    let m4 = mat;
    KernelRegistry::new()
        .bind(CholTask::Potrf, move |view: TaskView<'_>| {
            let (_, _, k) = decode(view.data);
            unsafe { potrf(m1.tile_mut(k, k), m1.b) }
        })
        .bind(CholTask::Trsm, move |view: TaskView<'_>| {
            let (i, _, k) = decode(view.data);
            unsafe { trsm(m2.tile(k, k), m2.tile_mut(i, k), m2.b) }
        })
        .bind(CholTask::Syrk, move |view: TaskView<'_>| {
            let (i, _, k) = decode(view.data);
            unsafe { syrk(m3.tile(i, k), m3.tile_mut(i, i), m3.b) }
        })
        .bind(CholTask::Gemm, move |view: TaskView<'_>| {
            let (i, j, k) = decode(view.data);
            unsafe { gemm_nt(m4.tile(i, k), m4.tile(j, k), m4.tile_mut(i, j), m4.b) }
        })
}

/// Execute one Cholesky task against the tiled matrix — the legacy
/// closure-dispatch compat shim; in-tree code executes via [`registry`].
pub fn exec_task(mat: &TiledMatrix, view: crate::coordinator::TaskView<'_>) {
    let (i, j, k) = decode(view.data);
    let b = mat.b;
    unsafe {
        match CholTask::from_u32(view.type_id) {
            CholTask::Potrf => potrf(mat.tile_mut(k, k), b),
            CholTask::Trsm => trsm(mat.tile(k, k), mat.tile_mut(i, k), b),
            CholTask::Syrk => syrk(mat.tile(i, k), mat.tile_mut(i, i), b),
            CholTask::Gemm => {
                gemm_nt(mat.tile(i, k), mat.tile(j, k), mat.tile_mut(i, j), b)
            }
        }
    }
}

/// Generate a random SPD tiled matrix: `A = M·Mᵀ + n·I`.
pub fn random_spd(b: usize, n: usize, seed: u64) -> TiledMatrix {
    let dim = b * n;
    let mut rng = Rng::new(seed);
    let m: Vec<f64> = (0..dim * dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let mut a = vec![0.0; dim * dim];
    for r in 0..dim {
        for c in 0..=r {
            let mut s = if r == c { dim as f64 } else { 0.0 };
            for p in 0..dim {
                s += m[r * dim + p] * m[c * dim + p];
            }
            a[r * dim + c] = s;
            a[c * dim + r] = s;
        }
    }
    TiledMatrix::from_dense(b, n, n, &a)
}

/// `‖A − L·Lᵀ‖_F / ‖A‖_F` using the lower-triangular tiles of the
/// factorized matrix.
pub fn residual(a0: &[f64], mat: &TiledMatrix) -> f64 {
    let dim = mat.b * mat.nt;
    let dense = mat.to_dense();
    // Extract L (lower triangle incl. diagonal).
    let mut l = vec![0.0; dim * dim];
    for r in 0..dim {
        for c in 0..=r {
            l[r * dim + c] = dense[r * dim + c];
        }
    }
    let mut diff = vec![0.0; dim * dim];
    for r in 0..dim {
        for c in 0..dim {
            let mut s = 0.0;
            for p in 0..=r.min(c) {
                s += l[r * dim + p] * l[c * dim + p];
            }
            diff[r * dim + c] = a0[r * dim + c] - s;
        }
    }
    fro_norm(&diff) / fro_norm(a0)
}

/// Factorize in place on `threads` workers.
pub fn run_threaded(
    mat: &TiledMatrix,
    config: SchedConfig,
    threads: usize,
) -> crate::coordinator::Result<crate::coordinator::RunMetrics> {
    let mut sched = crate::coordinator::Scheduler::new(config)?;
    build_tasks(&mut sched, mat.nt);
    sched.prepare()?;
    sched.run_registry(threads, &registry(mat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Scheduler;

    #[test]
    fn potrf_single_tile() {
        let mat = random_spd(6, 1, 1);
        let a0 = mat.to_dense();
        run_threaded(&mat, SchedConfig::new(1), 1).unwrap();
        let res = residual(&a0, &mat);
        assert!(res < 1e-12, "residual {res}");
    }

    #[test]
    fn cholesky_multi_tile_multithread() {
        for (b, n, threads) in [(4usize, 2usize, 2usize), (8, 4, 4), (4, 5, 3)] {
            let mat = random_spd(b, n, (b + n) as u64);
            let a0 = mat.to_dense();
            run_threaded(&mat, SchedConfig::new(threads), threads).unwrap();
            let res = residual(&a0, &mat);
            assert!(res < 1e-12, "b={b} n={n}: residual {res}");
        }
    }

    #[test]
    fn task_counts_analytic() {
        // N potrf + N(N-1)/2 trsm + N(N-1)/2 syrk + N(N-1)(N-2)/6 gemm.
        let n = 6;
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        build_tasks(&mut s, n);
        s.prepare().unwrap();
        let expected = n + n * (n - 1) / 2 * 2 + n * (n - 1) * (n - 2) / 6;
        assert_eq!(s.stats().tasks, expected);
        assert_eq!(s.stats().resources, n * n);
        assert_eq!(s.stats().roots, 1, "only POTRF(0) ready initially");
    }

    #[test]
    fn matches_reference_cholesky() {
        // Compare L against a dense reference factorization.
        let b = 4;
        let n = 3;
        let mat = random_spd(b, n, 9);
        let a0 = mat.to_dense();
        run_threaded(&mat, SchedConfig::new(2), 2).unwrap();
        let dim = b * n;
        let mut aref = a0.clone();
        // dense reference potrf
        potrf_dense(&mut aref, dim);
        let dense = mat.to_dense();
        for r in 0..dim {
            for c in 0..=r {
                assert!(
                    (dense[r * dim + c] - aref[r * dim + c]).abs() < 1e-10,
                    "L[{r},{c}]: {} vs {}",
                    dense[r * dim + c],
                    aref[r * dim + c]
                );
            }
        }
    }

    fn potrf_dense(a: &mut [f64], n: usize) {
        for k in 0..n {
            let mut d = a[k * n + k];
            for p in 0..k {
                d -= a[k * n + p] * a[k * n + p];
            }
            let d = d.sqrt();
            a[k * n + k] = d;
            for i in k + 1..n {
                let mut s = a[i * n + k];
                for p in 0..k {
                    s -= a[i * n + p] * a[k * n + p];
                }
                a[i * n + k] = s / d;
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive definite")]
    fn rejects_indefinite_matrix() {
        let b = 4;
        let mut a = vec![0.0; b * b];
        a[0] = -1.0;
        potrf(&mut a, b);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let m1 = random_spd(4, 3, 5);
        let m2 = random_spd(4, 3, 5);
        run_threaded(&m1, SchedConfig::new(1), 1).unwrap();
        run_threaded(&m2, SchedConfig::new(4), 4).unwrap();
        let (d1, d2) = (m1.to_dense(), m2.to_dense());
        let dim = 12;
        for r in 0..dim {
            for c in 0..=r {
                assert!((d1[r * dim + c] - d2[r * dim + c]).abs() < 1e-12);
            }
        }
    }
}
