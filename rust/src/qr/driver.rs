//! End-to-end tiled QR driver: builds the task graph, binds the four
//! tile kernels of a pluggable backend (native rust or the AOT-compiled
//! XLA artifacts) into a [`KernelRegistry`] ([`registry`]), and runs it
//! on the threaded executor or the virtual-time simulator.

use std::ops::Deref;

use crate::coordinator::{
    CostModel, KernelRegistry, RunMetrics, SchedConfig, Scheduler, SimCtx, TaskView,
};

use super::kernels;
use super::matrix::TiledMatrix;
use super::tasks::{build_tasks, decode, QrGraph, QrTask};

/// Pluggable tile-kernel backend. The native implementation lives in
/// [`super::kernels`]; the XLA/PJRT-backed one in [`crate::runtime`]
/// (see `rust/tests/xla_backend.rs` and `examples/e2e_xla.rs`).
pub trait TileBackend: Sync {
    fn geqrf(&self, a: &mut [f64], tau: &mut [f64], b: usize);
    fn larft(&self, v: &[f64], tau: &[f64], c: &mut [f64], b: usize);
    fn tsqrt(&self, r: &mut [f64], a: &mut [f64], tau: &mut [f64], b: usize);
    fn ssrft(&self, v2: &[f64], tau: &[f64], c_kj: &mut [f64], c_ij: &mut [f64], b: usize);
    fn name(&self) -> &'static str;
}

/// Pure-rust kernels (used for calibration and the large benches).
pub struct NativeBackend;

impl TileBackend for NativeBackend {
    fn geqrf(&self, a: &mut [f64], tau: &mut [f64], b: usize) {
        kernels::geqrf(a, tau, b)
    }
    fn larft(&self, v: &[f64], tau: &[f64], c: &mut [f64], b: usize) {
        kernels::larft_apply(v, tau, c, b)
    }
    fn tsqrt(&self, r: &mut [f64], a: &mut [f64], tau: &mut [f64], b: usize) {
        kernels::tsqrt(r, a, tau, b)
    }
    fn ssrft(&self, v2: &[f64], tau: &[f64], c_kj: &mut [f64], c_ij: &mut [f64], b: usize) {
        kernels::ssrft(v2, tau, c_kj, c_ij, b)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Bind the four QR kernels of `backend` against `mat` into a
/// [`KernelRegistry`] — the one task-type → kernel map every executor
/// (threaded, virtual-time, server pool) dispatches through.
///
/// `mat` and `backend` are any cloneable handles dereferencing to the
/// matrix/backend: plain references for a stack-scoped run, `Arc`s for
/// a `KernelRegistry<'static>` the server can own.
///
/// Safety of the raw tile accesses inside the kernels: the task graph's
/// locks and chains guarantee exclusivity — GEQRF/TSQRT own their V
/// tiles via locks, LARFT/SSRFT read V tiles only after the producing
/// task (dependency) and write their target tiles under locks; writes
/// to the shared diagonal/row tiles are serialized by the `(i-1,j,k)`
/// chains.
pub fn registry<'a, M, P, B>(mat: M, backend: P) -> KernelRegistry<'a>
where
    M: Deref<Target = TiledMatrix> + Clone + Send + Sync + 'a,
    P: Deref<Target = B> + Clone + Send + Sync + 'a,
    B: TileBackend + ?Sized,
{
    let (m1, b1) = (mat.clone(), backend.clone());
    let (m2, b2) = (mat.clone(), backend.clone());
    let (m3, b3) = (mat.clone(), backend.clone());
    let (m4, b4) = (mat, backend);
    KernelRegistry::new()
        .bind(QrTask::Geqrf, move |view: TaskView<'_>| {
            let (_, _, k) = decode(view.data);
            let b = m1.b;
            unsafe { b1.geqrf(m1.tile_mut(k, k), m1.tau_diag_mut(k), b) }
        })
        .bind(QrTask::Larft, move |view: TaskView<'_>| {
            let (_, j, k) = decode(view.data);
            let b = m2.b;
            unsafe { b2.larft(m2.tile(k, k), m2.tau_diag(k), m2.tile_mut(k, j), b) }
        })
        .bind(QrTask::Tsqrt, move |view: TaskView<'_>| {
            let (i, _, k) = decode(view.data);
            let b = m3.b;
            unsafe { b3.tsqrt(m3.tile_mut(k, k), m3.tile_mut(i, k), m3.tau_ts_mut(i, k), b) }
        })
        .bind(QrTask::Ssrft, move |view: TaskView<'_>| {
            let (i, j, k) = decode(view.data);
            let b = m4.b;
            unsafe {
                b4.ssrft(m4.tile(i, k), m4.tau_ts(i, k), m4.tile_mut(k, j), m4.tile_mut(i, j), b)
            }
        })
}

/// Execute one QR task against the matrix — the legacy closure-dispatch
/// compat shim (a `match` on the type id). In-tree code executes via
/// [`registry`]; this remains for out-of-tree callers and the
/// paper-fidelity tests.
pub fn exec_task<B: TileBackend>(mat: &TiledMatrix, backend: &B, view: TaskView<'_>) {
    let (i, j, k) = decode(view.data);
    let b = mat.b;
    unsafe {
        match QrTask::from_u32(view.type_id) {
            QrTask::Geqrf => {
                backend.geqrf(mat.tile_mut(k, k), mat.tau_diag_mut(k), b);
            }
            QrTask::Larft => {
                backend.larft(mat.tile(k, k), mat.tau_diag(k), mat.tile_mut(k, j), b);
            }
            QrTask::Tsqrt => {
                backend.tsqrt(mat.tile_mut(k, k), mat.tile_mut(i, k), mat.tau_ts_mut(i, k), b);
            }
            QrTask::Ssrft => {
                backend.ssrft(
                    mat.tile(i, k),
                    mat.tau_ts(i, k),
                    mat.tile_mut(k, j),
                    mat.tile_mut(i, j),
                    b,
                );
            }
        }
    }
}

/// Result of a full QR run.
pub struct QrRun {
    pub metrics: RunMetrics,
    pub graph: QrGraph,
}

/// Factorize `mat` in place using `nr_threads` workers.
pub fn run_threaded<B: TileBackend>(
    mat: &TiledMatrix,
    backend: &B,
    config: SchedConfig,
    nr_threads: usize,
) -> crate::coordinator::Result<QrRun> {
    let mut sched = Scheduler::new(config)?;
    let graph = build_tasks(&mut sched, mat.mt, mat.nt);
    sched.prepare()?;
    let metrics = sched.run_registry(nr_threads, &registry(mat, backend))?;
    Ok(QrRun { metrics, graph })
}

/// Cost model for the QR simulation: task cost is in units of b³ flops;
/// `ns_per_unit` is calibrated from a single-core native run (see
/// `bench/fig8.rs`). QR kernels are compute-bound (each b×b tile is
/// reused b times), so no memory-contention term is applied.
pub struct QrCostModel {
    pub ns_per_unit: f64,
}

impl CostModel for QrCostModel {
    fn duration_ns(&self, view: TaskView<'_>, _ctx: &SimCtx) -> u64 {
        ((view.cost as f64) * self.ns_per_unit).max(1.0) as u64
    }
}

/// Schedule the QR task graph on `cores` virtual cores (no numerics:
/// durations from `model`). Used for the Fig 8/9 strong-scaling curves.
pub fn run_sim<M: CostModel>(
    mt: usize,
    nt: usize,
    config: SchedConfig,
    cores: usize,
    model: &M,
) -> crate::coordinator::Result<QrRun> {
    let mut sched = Scheduler::new(config)?;
    let graph = build_tasks(&mut sched, mt, nt);
    sched.prepare()?;
    let metrics = sched.run_sim(cores, model)?;
    Ok(QrRun { metrics, graph })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qr::matrix::{fro_norm, gram};

    /// ‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F — orthogonal-invariance residual; tiny iff
    /// the factorization is a valid QR of A.
    pub fn qr_residual(a0: &[f64], mat: &TiledMatrix) -> f64 {
        let rows = mat.mt * mat.b;
        let cols = mat.nt * mat.b;
        let r = mat.extract_r();
        let g0 = gram(a0, rows, cols);
        let gr = gram(&r, rows, cols);
        let diff: Vec<f64> = g0.iter().zip(&gr).map(|(x, y)| x - y).collect();
        fro_norm(&diff) / fro_norm(&g0)
    }

    #[test]
    fn qr_2x2_tiles_single_thread() {
        let mat = TiledMatrix::random(8, 2, 2, 1);
        let a0 = mat.to_dense();
        let run = run_threaded(&mat, &NativeBackend, SchedConfig::new(1), 1).unwrap();
        assert_eq!(run.metrics.tasks_run, 5);
        let res = qr_residual(&a0, &mat);
        assert!(res < 1e-12, "residual {res}");
    }

    #[test]
    fn qr_4x4_tiles_multithread() {
        let mat = TiledMatrix::random(8, 4, 4, 2);
        let a0 = mat.to_dense();
        let run = run_threaded(&mat, &NativeBackend, SchedConfig::new(4), 4).unwrap();
        // 4 GEQRF + 6 LARFT + 6 TSQRT + 14 SSRFT = 30 tasks for 4x4 tiles.
        assert_eq!(run.metrics.tasks_run, 30);
        let res = qr_residual(&a0, &mat);
        assert!(res < 1e-12, "residual {res}");
    }

    #[test]
    fn qr_matches_across_thread_counts() {
        // The factorization is deterministic regardless of scheduling
        // because every kernel's inputs are fixed by the graph.
        let m1 = TiledMatrix::random(4, 3, 3, 3);
        let m2 = TiledMatrix::random(4, 3, 3, 3);
        run_threaded(&m1, &NativeBackend, SchedConfig::new(1), 1).unwrap();
        run_threaded(&m2, &NativeBackend, SchedConfig::new(4), 4).unwrap();
        let d1 = m1.to_dense();
        let d2 = m2.to_dense();
        for (x, y) in d1.iter().zip(&d2) {
            assert!((x - y).abs() < 1e-13, "{x} vs {y}");
        }
    }

    #[test]
    fn qr_tall_matrix() {
        let mat = TiledMatrix::random(4, 5, 2, 9);
        let a0 = mat.to_dense();
        run_threaded(&mat, &NativeBackend, SchedConfig::new(2), 2).unwrap();
        let res = qr_residual(&a0, &mat);
        assert!(res < 1e-12, "residual {res}");
    }

    #[test]
    fn sim_runs_full_graph() {
        let run = run_sim(8, 8, SchedConfig::new(4), 4, &QrCostModel { ns_per_unit: 100.0 })
            .unwrap();
        let n_tasks = 8 + 2 * (8 * 7 / 2) + 7 * 8 * 15 / 6;
        assert_eq!(run.metrics.tasks_run, n_tasks);
        assert!(run.metrics.check_no_worker_overlap());
    }

    #[test]
    fn sim_scales_with_cores() {
        let t = |cores| {
            run_sim(
                16,
                16,
                SchedConfig::new(cores),
                cores,
                &QrCostModel { ns_per_unit: 50.0 },
            )
            .unwrap()
            .metrics
            .elapsed_ns
        };
        let t1 = t(1);
        let t8 = t(8);
        let speedup = t1 as f64 / t8 as f64;
        assert!(speedup > 4.0, "speedup {speedup} too low for 16x16 tiles on 8 cores");
        assert!(speedup <= 8.001);
    }
}
