//! Tiled QR decomposition substrate (paper §4.1, Buttari et al. 2009).
//!
//! A 2048×2048 matrix with 64×64 tiles factorized by four kernels
//! (GEQRF/LARFT/TSQRT/SSRFT) whose task graph is scheduled by the
//! QuickSched coordinator. Kernels run either natively ([`kernels`])
//! or through the AOT-compiled Pallas/XLA artifacts ([`crate::runtime`]).
//! [`cholesky`] adds the tiled Cholesky factorization (the sibling
//! PLASMA algorithm from Buttari et al. 2009) as an extension workload.
pub mod cholesky;
pub mod driver;
pub mod kernels;
pub mod matrix;
pub mod tasks;
pub mod verify;

pub use driver::{
    exec_task, registry, run_sim, run_threaded, NativeBackend, QrCostModel, QrRun, TileBackend,
};
pub use matrix::TiledMatrix;
pub use tasks::{build_tasks, QrGraph, QrTask};
