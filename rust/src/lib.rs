//! QuickSched-RS: task-based parallelism with dependencies and conflicts.
//!
//! Reproduction of Gonnet, Chalk & Schaller (2016) as a three-layer
//! Rust + JAX + Pallas system. The crate is organized as:
//!
//! * [`coordinator`] — the QuickSched scheduler itself (the paper's
//!   contribution): tasks, hierarchical resources, max-heap queues,
//!   critical-path weights, work stealing, threaded + virtual-time
//!   executors.
//! * [`runtime`] — PJRT runtime service loading AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py`.
//! * [`qr`] — tiled QR decomposition substrate (paper §4.1).
//! * [`nbody`] — Barnes-Hut N-body substrate (paper §4.2).
//! * [`baselines`] — dependency-only scheduler (OmpSs stand-in).
//! * [`bench`] — drivers regenerating every table/figure of §4.
//! * [`server`] — persistent multi-graph scheduling service: one
//!   long-lived worker pool serving concurrent job submissions from
//!   many tenants, with graph-template reuse and weighted-fair
//!   admission (`repro serve` / `repro bench-server`).
//! * [`util`] — RNG, stats, mini bench harness, CLI parsing.
pub mod util;
pub mod coordinator;
pub mod runtime;
pub mod qr;
pub mod nbody;
pub mod baselines;
pub mod bench;
pub mod server;
