//! QuickSched-RS: task-based parallelism with dependencies and conflicts.
//!
//! Reproduction of Gonnet, Chalk & Schaller (2016) as a three-layer
//! Rust + JAX + Pallas system. The crate is organized as:
//!
//! * [`coordinator`] — the QuickSched scheduler itself (the paper's
//!   contribution): tasks, hierarchical resources, max-heap queues,
//!   critical-path weights, work stealing, threaded + virtual-time
//!   executors.
//! * [`runtime`] — PJRT runtime service loading AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py`.
//! * [`qr`] — tiled QR decomposition substrate (paper §4.1).
//! * [`nbody`] — Barnes-Hut N-body substrate (paper §4.2).
//! * [`baselines`] — dependency-only scheduler (OmpSs stand-in).
//! * [`bench`] — drivers regenerating every table/figure of §4.
//! * [`server`] — persistent multi-graph scheduling service: one
//!   long-lived worker pool serving concurrent job submissions from
//!   many tenants through a *shared sharded ready-queue layer*
//!   ([`server::shard`]), with graph-template reuse, weighted-fair
//!   admission, and batched (fused) admission for sub-millisecond jobs
//!   (`repro serve` / `repro bench-server [--batch]`). Its network
//!   edge is [`server::wire`]: a std-only framed wire protocol served
//!   over TCP or Unix-domain sockets (`repro serve --listen`).
//! * [`client`] — `RemoteClient`, the blocking client library for the
//!   wire protocol (typed payload args, in-process error types).
//! * [`obs`] — observability: the `MetricsRegistry` (Prometheus
//!   text-format exposition of the always-on scheduler/queue/shard/
//!   admission/wire counters, served end-to-end via the `Metrics` wire
//!   request and `repro serve --metrics`) and the `TraceSink` (Chrome
//!   `trace_event` timelines — the Fig 9/12 Gantt view — written by
//!   `repro trace`).
//! * [`sim`] — deterministic simulation testing (DST): a whole-server
//!   simulator on a virtual clock that drives the real admission /
//!   registry / scheduler / codec stack through simulated connections
//!   with seeded fault injection (drops, dups, reorders, slow reads,
//!   resets, partitions, reconnect/replay/drain hostilities), checks six
//!   end-to-end invariants every run — including exactly-once execution
//!   per idempotency key — and replays any schedule from a single `u64`
//!   seed (`repro sim --seeds A..B`).
//! * [`util`] — RNG, stats, mini bench harness, CLI parsing.
//!
//! # Architecture at a glance
//!
//! A task travels: `TaskSpec` build → `prepare()` (validation + freeze
//! into the CSR/SoA `CompiledGraph`: shared adjacency/payload arenas,
//! sorted lock sets, critical-path weights, padded per-run atomics —
//! see ARCHITECTURE.md §Memory layout) → ready announcement — into the
//! scheduler's own queues for single-graph runs, or into a cross-job
//! shard (tagged `(job, task, weight)`) on the server — → acquisition
//! (`gettask` / `try_acquire`, resources locked) → execution →
//! `complete()` (unlock, wake dependents). The server stacks admission
//! (fair queue + job fusion), the template registry (build-once,
//! `reset_run()`-recycle), and per-tenant stats around that inner loop.
//!
//! Start with the repo-level `README.md` for the quickstart, and
//! `ARCHITECTURE.md` for the jobs → shards → workers data-flow diagram
//! and the routing / steal / batching policies.
pub mod util;
pub mod coordinator;
pub mod runtime;
pub mod qr;
pub mod nbody;
pub mod baselines;
pub mod bench;
pub mod server;
pub mod client;
pub mod obs;
pub mod sim;
