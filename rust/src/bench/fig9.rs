//! **Fig. 9** — per-core task timelines of the QR decomposition on 64
//! cores, QuickSched vs the dependency-only baseline.
//!
//! Emits the two Gantt CSVs (`fig9_quicksched.csv`, `fig9_dep_only.csv`,
//! columns `worker,start_ns,end_ns,type,tid,stolen`) and prints the
//! summary statistic the paper's figure makes visible: QuickSched
//! schedules the critical-path DGEQRF tasks *early* (as soon as they
//! become available), the baseline lets them straggle, which shows up
//! as a later last-GEQRF finish and a longer makespan tail.

use crate::baselines::DepOnlyBuilder;
use crate::coordinator::{RunMetrics, SchedConfig};
use crate::qr::{self, QrTask};

use super::harness::{ms, out_dir, x2, Table};

pub struct Fig9Opts {
    pub tiles: usize,
    pub tile: usize,
    pub cores: usize,
    pub calib_tiles: usize,
}

impl Default for Fig9Opts {
    fn default() -> Self {
        Self { tiles: 32, tile: 64, cores: 64, calib_tiles: 8 }
    }
}

impl Fig9Opts {
    pub fn quick() -> Self {
        Self { tiles: 12, tile: 16, cores: 16, calib_tiles: 4 }
    }
}

/// Mean start time of the GEQRF tasks as a fraction of the makespan.
/// Lower is better: GEQRFs sit on the longest critical path, and the
/// visible difference in the paper's Fig. 9 is that QuickSched starts
/// them "as soon as they become available" while OmpSs lets them
/// straggle. (The *last* GEQRF is by construction the final task of the
/// DAG, so its end time is uninformative — the mean start captures the
/// whole column.)
pub fn geqrf_mean_start_fraction(m: &RunMetrics) -> f64 {
    let starts: Vec<u64> = m
        .timeline
        .iter()
        .filter(|r| r.type_id == QrTask::Geqrf as u32)
        .map(|r| r.start_ns)
        .collect();
    if starts.is_empty() || m.elapsed_ns == 0 {
        return 0.0;
    }
    starts.iter().map(|&s| s as f64).sum::<f64>()
        / starts.len() as f64
        / m.elapsed_ns as f64
}

pub fn run(opts: &Fig9Opts) -> (Table, RunMetrics, RunMetrics) {
    let ns_per_unit = super::calibrate::qr_ns_per_unit(opts.calib_tiles, opts.tile);
    let model = qr::QrCostModel { ns_per_unit };

    let cfg = SchedConfig::new(opts.cores).with_seed(42).with_timeline(true);
    let qs = qr::run_sim(opts.tiles, opts.tiles, cfg, opts.cores, &model)
        .unwrap()
        .metrics;

    let dep = {
        let cfg = SchedConfig::new(opts.cores).with_seed(42).with_timeline(true);
        let mut b = DepOnlyBuilder::new_with_config(cfg).unwrap();
        qr::build_tasks(&mut b, opts.tiles, opts.tiles);
        let mut s = b.finish().unwrap();
        s.run_sim(opts.cores, &model).unwrap()
    };

    let dir = out_dir();
    std::fs::create_dir_all(&dir).ok();
    let mut f = std::fs::File::create(dir.join("fig9_quicksched.csv")).unwrap();
    qs.write_timeline_csv(&mut f).unwrap();
    let mut f = std::fs::File::create(dir.join("fig9_dep_only.csv")).unwrap();
    dep.write_timeline_csv(&mut f).unwrap();

    let mut t = Table::new(&["scheduler", "makespan_ms", "geqrf_mean_start", "util"]);
    t.row(&[
        "quicksched".into(),
        ms(qs.elapsed_ns),
        x2(geqrf_mean_start_fraction(&qs)),
        x2(qs.utilization()),
    ]);
    t.row(&[
        "dep_only".into(),
        ms(dep.elapsed_ns),
        x2(geqrf_mean_start_fraction(&dep)),
        x2(dep.utilization()),
    ]);
    let _ = t.write_csv(&dir.join("fig9_summary.csv"));
    (t, qs, dep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig9_geqrf_scheduled_early() {
        let (_t, qs, dep) = run(&Fig9Opts::quick());
        assert!(!qs.timeline.is_empty());
        assert!(!dep.timeline.is_empty());
        let f_qs = geqrf_mean_start_fraction(&qs);
        let f_dep = geqrf_mean_start_fraction(&dep);
        // The critical-path scheduler must start its GEQRFs no later
        // (relative to its own makespan) than the FIFO baseline.
        assert!(
            f_qs <= f_dep + 0.02,
            "GEQRF mean-start fractions: qs {f_qs:.3} vs dep {f_dep:.3}"
        );
        assert!(qs.check_no_worker_overlap());
        assert!(dep.check_no_worker_overlap());
    }
}
