//! **E9** — ablations over the scheduler's design choices, including the
//! §5 future-work extensions implemented in this repo:
//!
//! * key policy: critical-path (paper) vs FIFO vs cost-only,
//! * work stealing: random (paper) vs weight-aware (§5),
//! * resource re-owning on/off (§3.4 / §4.2),
//! * lock-aware priorities on/off (§5).
//!
//! Run over both applications' task graphs on 64 virtual cores.

use crate::coordinator::{KeyPolicy, SchedConfig, Scheduler, StealPolicy};
use crate::nbody;
use crate::qr;

use super::harness::{ms, out_dir, x2, Table};

pub struct AblationOpts {
    pub qr_tiles: usize,
    pub nb_n: usize,
    pub nb_n_max: usize,
    pub nb_n_task: usize,
    pub cores: usize,
    pub reps: usize,
}

impl Default for AblationOpts {
    fn default() -> Self {
        Self { qr_tiles: 32, nb_n: 200_000, nb_n_max: 100, nb_n_task: 2000, cores: 64, reps: 3 }
    }
}

impl AblationOpts {
    pub fn quick() -> Self {
        Self { qr_tiles: 12, nb_n: 30_000, nb_n_max: 100, nb_n_task: 800, cores: 16, reps: 1 }
    }
}

#[derive(Clone, Copy)]
pub struct Variant {
    pub name: &'static str,
    pub key: KeyPolicy,
    pub steal: StealPolicy,
    pub reown: bool,
    pub lock_aware: bool,
}

pub const VARIANTS: [Variant; 6] = [
    Variant { name: "paper", key: KeyPolicy::CriticalPath, steal: StealPolicy::Random, reown: true, lock_aware: false },
    Variant { name: "fifo-keys", key: KeyPolicy::Fifo, steal: StealPolicy::Random, reown: true, lock_aware: false },
    Variant { name: "cost-keys", key: KeyPolicy::Cost, steal: StealPolicy::Random, reown: true, lock_aware: false },
    Variant { name: "weight-steal", key: KeyPolicy::CriticalPath, steal: StealPolicy::WeightAware, reown: true, lock_aware: false },
    Variant { name: "no-reown", key: KeyPolicy::CriticalPath, steal: StealPolicy::Random, reown: false, lock_aware: false },
    Variant { name: "lock-aware", key: KeyPolicy::CriticalPath, steal: StealPolicy::Random, reown: true, lock_aware: true },
];

fn config(v: &Variant, cores: usize, seed: u64) -> SchedConfig {
    let mut cfg = SchedConfig::new(cores).with_seed(seed);
    cfg.flags.key_policy = v.key;
    cfg.flags.steal = v.steal;
    cfg.flags.reown = v.reown;
    cfg.flags.lock_aware_priority = v.lock_aware;
    cfg
}

pub fn run(opts: &AblationOpts) -> Table {
    let qr_model = qr::QrCostModel { ns_per_unit: 400.0 };
    let nb_model = nbody::nb_cost_model(3.0);
    let cloud = nbody::uniform_cloud(opts.nb_n, 77);

    let mut table = Table::new(&["variant", "qr_ms", "qr_vs_paper", "bh_ms", "bh_vs_paper"]);
    let mut qr_base = 0u64;
    let mut bh_base = 0u64;
    for v in &VARIANTS {
        let mut qr_total = 0u64;
        let mut bh_total = 0u64;
        for rep in 0..opts.reps {
            let mut s = Scheduler::new(config(v, opts.cores, 500 + rep as u64)).unwrap();
            qr::build_tasks(&mut s, opts.qr_tiles, opts.qr_tiles);
            s.prepare().unwrap();
            qr_total += s.run_sim(opts.cores, &qr_model).unwrap().elapsed_ns;

            let run = nbody::run_sim(
                cloud.clone(),
                opts.nb_n_max,
                opts.nb_n_task,
                config(v, opts.cores, 600 + rep as u64),
                opts.cores,
                &nb_model,
            )
            .unwrap();
            bh_total += run.metrics.elapsed_ns;
        }
        let qr_ns = qr_total / opts.reps as u64;
        let bh_ns = bh_total / opts.reps as u64;
        if v.name == "paper" {
            qr_base = qr_ns;
            bh_base = bh_ns;
        }
        table.row(&[
            v.name.into(),
            ms(qr_ns),
            x2(qr_ns as f64 / qr_base as f64),
            ms(bh_ns),
            x2(bh_ns as f64 / bh_base as f64),
        ]);
    }
    let _ = table.write_csv(&out_dir().join("ablation.csv"));
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_ablation_runs_all_variants() {
        let t = run(&AblationOpts::quick());
        let s = t.render();
        for v in &VARIANTS {
            assert!(s.contains(v.name), "missing {}", v.name);
        }
    }
}
