//! **Fig. 12** — task timeline of the Barnes-Hut tree-code on 64 cores:
//! red self-interactions, green particle–particle pairs, blue
//! particle–cell walks (plus the COM pre-pass the paper folds into
//! startup). Emits `fig12_bh_timeline.csv` and summary occupancy stats.

use crate::coordinator::{RunMetrics, SchedConfig};
use crate::nbody::{self, NbTask};

use super::harness::{ms, out_dir, x2, Table};

pub struct Fig12Opts {
    pub n: usize,
    pub n_max: usize,
    pub n_task: usize,
    pub cores: usize,
    pub calib_n: usize,
}

impl Default for Fig12Opts {
    fn default() -> Self {
        Self { n: 1_000_000, n_max: 100, n_task: 5000, cores: 64, calib_n: 30_000 }
    }
}

impl Fig12Opts {
    pub fn quick() -> Self {
        Self { n: 50_000, n_max: 100, n_task: 1200, cores: 16, calib_n: 8_000 }
    }
}

pub fn run(opts: &Fig12Opts) -> (Table, RunMetrics) {
    let ns_task = super::calibrate::nb_ns_per_unit(
        opts.calib_n,
        opts.n_max,
        opts.n_task.min(opts.calib_n / 8).max(64),
    );
    let model = nbody::nb_cost_model(ns_task);
    let cfg = SchedConfig::new(opts.cores).with_seed(7).with_timeline(true);
    let run = nbody::run_sim(
        nbody::uniform_cloud(opts.n, 1234),
        opts.n_max,
        opts.n_task,
        cfg,
        opts.cores,
        &model,
    )
    .unwrap();
    let m = run.metrics;

    let dir = out_dir();
    std::fs::create_dir_all(&dir).ok();
    let mut f = std::fs::File::create(dir.join("fig12_bh_timeline.csv")).unwrap();
    m.write_timeline_csv(&mut f).unwrap();

    let mut table = Table::new(&["task_type", "count", "total_ms", "share"]);
    let by_type = m.cost_by_type();
    let total: u64 = by_type.iter().map(|&(_, ns)| ns).sum();
    for (ty, ns) in &by_type {
        let count = m.timeline.iter().filter(|r| r.type_id == *ty).count();
        table.row(&[
            NbTask::from_u32(*ty).name().to_string(),
            count.to_string(),
            ms(*ns),
            x2(*ns as f64 / total as f64),
        ]);
    }
    table.row(&[
        "makespan".into(),
        m.workers.to_string(),
        ms(m.elapsed_ns),
        x2(m.utilization()),
    ]);
    let _ = table.write_csv(&dir.join("fig12_summary.csv"));
    (table, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig12_timeline() {
        let (_t, m) = run(&Fig12Opts::quick());
        assert!(m.check_no_worker_overlap());
        // All three interaction types present.
        let types: std::collections::HashSet<u32> =
            m.timeline.iter().map(|r| r.type_id).collect();
        for ty in [NbTask::SelfInteract, NbTask::PairPP, NbTask::PairPC] {
            assert!(types.contains(&(ty as u32)), "missing {:?}", ty.name());
        }
        // Interaction work dominates COM bookkeeping.
        let by = m.cost_by_type();
        let com = by
            .iter()
            .find(|(t, _)| *t == NbTask::Com as u32)
            .map(|&(_, ns)| ns)
            .unwrap_or(0);
        let total: u64 = by.iter().map(|&(_, ns)| ns).sum();
        assert!((com as f64) < 0.1 * total as f64, "COM share too high");
    }
}
