//! **Fig. 13** — accumulated cost of each Barnes-Hut task type, plus the
//! `qsched_gettask` overhead, summed over all cores, as the core count
//! grows. The paper's signature features: pair-interaction cost grows
//! ~30–40% past 32 cores (shared L2 contention), particle–cell only
//! ~10% (more compute per byte), scheduler overhead stays ~1%.

use crate::coordinator::SchedConfig;
use crate::nbody::{self, NbTask};

use super::harness::{ms, out_dir, x2, Table};

/// Fig. 13 samples the 32→64 contention ramp more densely than the
/// scaling figures.
pub const FIG13_CORES: [usize; 9] = [1, 2, 4, 8, 16, 32, 40, 48, 64];

pub struct Fig13Opts {
    pub n: usize,
    pub n_max: usize,
    pub n_task: usize,
    pub calib_n: usize,
}

impl Default for Fig13Opts {
    fn default() -> Self {
        Self { n: 1_000_000, n_max: 100, n_task: 5000, calib_n: 30_000 }
    }
}

impl Fig13Opts {
    pub fn quick() -> Self {
        Self { n: 50_000, n_max: 100, n_task: 1200, calib_n: 8_000 }
    }
}

pub struct Fig13Row {
    pub cores: usize,
    /// Accumulated ns per type id (indexed by NbTask).
    pub per_type: [u64; 4],
    pub gettask_ns: u64,
    pub overhead_frac: f64,
}

pub fn run(opts: &Fig13Opts) -> (Table, Vec<Fig13Row>) {
    let ns_task = super::calibrate::nb_ns_per_unit(
        opts.calib_n,
        opts.n_max,
        opts.n_task.min(opts.calib_n / 8).max(64),
    );
    let model = nbody::nb_cost_model(ns_task);
    let cloud = nbody::uniform_cloud(opts.n, 1234);

    let mut rows = Vec::new();
    for &cores in &FIG13_CORES {
        let cfg = SchedConfig::new(cores).with_seed(11).with_timeline(true);
        let m = nbody::run_sim(cloud.clone(), opts.n_max, opts.n_task, cfg, cores, &model)
            .unwrap()
            .metrics;
        let mut per_type = [0u64; 4];
        for (ty, ns) in m.cost_by_type() {
            per_type[ty as usize] = ns;
        }
        rows.push(Fig13Row {
            cores,
            per_type,
            gettask_ns: m.gettask_ns,
            overhead_frac: m.overhead_fraction(),
        });
    }

    let base = &rows[0];
    let mut table = Table::new(&[
        "cores",
        "self_ms",
        "pair_ms",
        "pc_ms",
        "com_ms",
        "gettask_ms",
        "overhead",
        "pair_growth",
        "pc_growth",
    ]);
    for r in &rows {
        table.row(&[
            r.cores.to_string(),
            ms(r.per_type[NbTask::SelfInteract as usize]),
            ms(r.per_type[NbTask::PairPP as usize]),
            ms(r.per_type[NbTask::PairPC as usize]),
            ms(r.per_type[NbTask::Com as usize]),
            ms(r.gettask_ns),
            x2(r.overhead_frac),
            x2(r.per_type[1] as f64 / base.per_type[1] as f64),
            x2(r.per_type[2] as f64 / base.per_type[2] as f64),
        ]);
    }
    let _ = table.write_csv(&out_dir().join("fig13_task_costs.csv"));
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig13_contention_shape() {
        let (_t, rows) = run(&Fig13Opts::quick());
        let base = &rows[0];
        let last = rows.last().unwrap();
        let pair_growth =
            last.per_type[1] as f64 / base.per_type[1].max(1) as f64;
        let pc_growth = last.per_type[2] as f64 / base.per_type[2].max(1) as f64;
        // Pair types inflate more than particle-cell (paper: 30-40% vs
        // 10% at full occupancy). The quick graph does not saturate all
        // 64 virtual cores uniformly across phases, attenuating the
        // absolute growths; the ordering and bounds must hold (the
        // full-scale numbers are recorded in EXPERIMENTS.md §E7).
        assert!(pair_growth > 1.05, "pair growth {pair_growth}");
        assert!(pc_growth < pair_growth, "pc {pc_growth} vs pair {pair_growth}");
        assert!((1.0..1.45).contains(&pc_growth), "pc growth {pc_growth}");
        assert!(pair_growth < 1.45, "pair growth {pair_growth}");
        // Scheduler overhead ~1% (paper's headline Fig 13 claim).
        assert!(
            last.overhead_frac < 0.05,
            "overhead fraction {}",
            last.overhead_frac
        );
    }
}
