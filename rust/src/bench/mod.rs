//! Bench drivers regenerating every table and figure of paper §4, plus
//! the in-repo measurement harness (no criterion in the offline
//! registry). Each driver has a paper-scale `Default` and a CI-scale
//! `quick()`; the `repro bench <name>` CLI and `cargo bench` targets
//! both route here, and each writes CSVs under `bench_out/`.
pub mod ablation;
pub mod calibrate;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig8;
pub mod fig9;
pub mod harness;
pub mod overhead;
