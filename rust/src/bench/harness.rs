//! In-repo measurement harness (no `criterion` in the offline registry):
//! warmup + fixed-sample timing with median/MAD reporting, simple table
//! rendering, and CSV output under `bench_out/`.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::stats::Summary;

/// Measure `f` after `warmup` untimed runs; returns per-run seconds.
pub fn sample<F: FnMut()>(mut f: F, warmup: usize, samples: usize) -> Vec<f64> {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Measure and summarize.
pub fn bench<F: FnMut()>(name: &str, f: F, warmup: usize, samples: usize) -> Summary {
    let s = Summary::of(&sample(f, warmup, samples));
    eprintln!(
        "  {name}: median {:.3} ms (±{:.3}, n={})",
        s.median * 1e3,
        s.stddev * 1e3,
        s.n
    );
    s
}

/// Simple fixed-width table printer for the figure/table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(out.len() - 1));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }
}

/// Output directory for bench CSVs (`QS_BENCH_OUT` or `bench_out/`).
pub fn out_dir() -> PathBuf {
    std::env::var_os("QS_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("bench_out"))
}

/// Format a nanosecond count as milliseconds with 3 digits.
pub fn ms(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1e6)
}

/// Format a ratio with 2 digits.
pub fn x2(r: f64) -> String {
    format!("{r:.2}")
}

/// Core counts used by the paper's strong-scaling figures.
pub const CORE_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_counts() {
        let mut n = 0;
        let s = sample(|| n += 1, 2, 5);
        assert_eq!(s.len(), 5);
        assert_eq!(n, 7);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new(&["cores", "ms"]);
        t.row(&["1".into(), "100.0".into()]);
        t.row(&["64".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("cores"));
        assert!(s.contains("64"));
        let p = std::env::temp_dir().join(format!("qs_tbl_{}.csv", std::process::id()));
        t.write_csv(&p).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert!(txt.starts_with("cores,ms"));
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(1_500_000), "1.500");
        assert_eq!(x2(1.234), "1.23");
    }
}
