//! **E8** — setup-cost accounting: the paper reports graph construction
//! (tasks + resources + dependencies) at 7.2 ms / ≤3% of total for QR
//! and 51.3 ms for Barnes-Hut. This driver measures our build times and
//! their fraction of a single-core solve.

use std::time::Instant;

use crate::coordinator::{SchedConfig, Scheduler};
use crate::nbody;
use crate::qr;

use super::harness::{out_dir, x2, Table};

pub struct OverheadOpts {
    pub qr_tiles: usize,
    pub qr_tile: usize,
    pub nb_n: usize,
    pub nb_n_max: usize,
    pub nb_n_task: usize,
}

impl Default for OverheadOpts {
    fn default() -> Self {
        Self { qr_tiles: 32, qr_tile: 64, nb_n: 1_000_000, nb_n_max: 100, nb_n_task: 5000 }
    }
}

impl OverheadOpts {
    pub fn quick() -> Self {
        Self { qr_tiles: 8, qr_tile: 16, nb_n: 50_000, nb_n_max: 100, nb_n_task: 1200 }
    }
}

pub fn run(opts: &OverheadOpts) -> Table {
    let mut table = Table::new(&["app", "graph_build_ms", "prepare_ms", "solve_ms", "setup_frac"]);

    // --- QR ---
    let mat = qr::TiledMatrix::random(opts.qr_tile, opts.qr_tiles, opts.qr_tiles, 5);
    let t0 = Instant::now();
    let mut sched = Scheduler::new(SchedConfig::new(1)).unwrap();
    qr::build_tasks(&mut sched, opts.qr_tiles, opts.qr_tiles);
    let build = t0.elapsed();
    let t0 = Instant::now();
    sched.prepare().unwrap();
    let prepare = t0.elapsed();
    let t0 = Instant::now();
    sched
        .run_registry(1, &qr::registry(&mat, &qr::NativeBackend))
        .unwrap();
    let solve = t0.elapsed();
    let setup = build + prepare;
    table.row(&[
        "qr".into(),
        format!("{:.3}", build.as_secs_f64() * 1e3),
        format!("{:.3}", prepare.as_secs_f64() * 1e3),
        format!("{:.3}", solve.as_secs_f64() * 1e3),
        x2(setup.as_secs_f64() / (setup + solve).as_secs_f64()),
    ]);

    // --- Barnes-Hut (graph build only at full scale; solve measured on
    //     the real particles — at 1M this is the long pole, so callers
    //     may prefer `quick()`) ---
    let cloud = nbody::uniform_cloud(opts.nb_n, 9);
    let tree = nbody::Octree::build(cloud, opts.nb_n_max);
    let state = nbody::NBodyState::from_tree(tree);
    let t0 = Instant::now();
    let mut sched = Scheduler::new(SchedConfig::new(1)).unwrap();
    nbody::build_tasks(&mut sched, &state, opts.nb_n_task);
    let build = t0.elapsed();
    let t0 = Instant::now();
    sched.prepare().unwrap();
    let prepare = t0.elapsed();
    let t0 = Instant::now();
    sched.run_registry(1, &nbody::registry(&state)).unwrap();
    let solve = t0.elapsed();
    let setup = build + prepare;
    table.row(&[
        "barnes-hut".into(),
        format!("{:.3}", build.as_secs_f64() * 1e3),
        format!("{:.3}", prepare.as_secs_f64() * 1e3),
        format!("{:.3}", solve.as_secs_f64() * 1e3),
        x2(setup.as_secs_f64() / (setup + solve).as_secs_f64()),
    ]);

    let _ = table.write_csv(&out_dir().join("overhead_setup.csv"));
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_overhead_small_fraction() {
        let t = run(&OverheadOpts::quick());
        let rendered = t.render();
        assert!(rendered.contains("qr"));
        assert!(rendered.contains("barnes-hut"));
    }
}
