//! **E8** — setup-cost accounting: the paper reports graph construction
//! (tasks + resources + dependencies) at 7.2 ms / ≤3% of total for QR
//! and 51.3 ms for Barnes-Hut. This driver measures our build times and
//! their fraction of a single-core solve.
//!
//! Also home of **`repro bench-core`** ([`run_core`]): the
//! core-scheduler overhead trajectory. It drives empty-kernel runs of
//! the synthetic, QR, and Barnes-Hut graphs through the real threaded
//! executor and reports the ns-per-task dispatch overhead (the paper's
//! Fig. 13 claim: per-task overhead stays in the microsecond range) and
//! the mean `gettask` heap-scan length, writing the repo's first
//! committed-core-path benchmark JSON to `bench_out/BENCH_core.json`.
//! CI runs the `--quick` variant and uploads the JSON as an artifact;
//! `rust/tests/perf_guard.rs` gates gross regressions with a ≥10×
//! headroom ceiling.

use std::io::Write as _;
use std::time::Instant;

use crate::coordinator::{GraphBuilder, RunMetrics, SchedConfig, Scheduler};
use crate::nbody;
use crate::qr;

use super::harness::{out_dir, x2, Table};

pub struct OverheadOpts {
    pub qr_tiles: usize,
    pub qr_tile: usize,
    pub nb_n: usize,
    pub nb_n_max: usize,
    pub nb_n_task: usize,
}

impl Default for OverheadOpts {
    fn default() -> Self {
        Self { qr_tiles: 32, qr_tile: 64, nb_n: 1_000_000, nb_n_max: 100, nb_n_task: 5000 }
    }
}

impl OverheadOpts {
    pub fn quick() -> Self {
        Self { qr_tiles: 8, qr_tile: 16, nb_n: 50_000, nb_n_max: 100, nb_n_task: 1200 }
    }
}

pub fn run(opts: &OverheadOpts) -> Table {
    let mut table = Table::new(&["app", "graph_build_ms", "prepare_ms", "solve_ms", "setup_frac"]);

    // --- QR ---
    let mat = qr::TiledMatrix::random(opts.qr_tile, opts.qr_tiles, opts.qr_tiles, 5);
    let t0 = Instant::now();
    let mut sched = Scheduler::new(SchedConfig::new(1)).unwrap();
    qr::build_tasks(&mut sched, opts.qr_tiles, opts.qr_tiles);
    let build = t0.elapsed();
    let t0 = Instant::now();
    sched.prepare().unwrap();
    let prepare = t0.elapsed();
    let t0 = Instant::now();
    sched
        .run_registry(1, &qr::registry(&mat, &qr::NativeBackend))
        .unwrap();
    let solve = t0.elapsed();
    let setup = build + prepare;
    table.row(&[
        "qr".into(),
        format!("{:.3}", build.as_secs_f64() * 1e3),
        format!("{:.3}", prepare.as_secs_f64() * 1e3),
        format!("{:.3}", solve.as_secs_f64() * 1e3),
        x2(setup.as_secs_f64() / (setup + solve).as_secs_f64()),
    ]);

    // --- Barnes-Hut (graph build only at full scale; solve measured on
    //     the real particles — at 1M this is the long pole, so callers
    //     may prefer `quick()`) ---
    let cloud = nbody::uniform_cloud(opts.nb_n, 9);
    let tree = nbody::Octree::build(cloud, opts.nb_n_max);
    let state = nbody::NBodyState::from_tree(tree);
    let t0 = Instant::now();
    let mut sched = Scheduler::new(SchedConfig::new(1)).unwrap();
    nbody::build_tasks(&mut sched, &state, opts.nb_n_task);
    let build = t0.elapsed();
    let t0 = Instant::now();
    sched.prepare().unwrap();
    let prepare = t0.elapsed();
    let t0 = Instant::now();
    sched.run_registry(1, &nbody::registry(&state)).unwrap();
    let solve = t0.elapsed();
    let setup = build + prepare;
    table.row(&[
        "barnes-hut".into(),
        format!("{:.3}", build.as_secs_f64() * 1e3),
        format!("{:.3}", prepare.as_secs_f64() * 1e3),
        format!("{:.3}", solve.as_secs_f64() * 1e3),
        x2(setup.as_secs_f64() / (setup + solve).as_secs_f64()),
    ]);

    let _ = table.write_csv(&out_dir().join("overhead_setup.csv"));
    table
}

// ----------------------------------------------------------------------
// bench-core: ns-per-task dispatch overhead on the frozen CSR layout
// ----------------------------------------------------------------------

pub struct CoreOpts {
    /// Worker threads for the empty-kernel runs (1 = the cleanest
    /// pure-overhead number; CI uses 1).
    pub threads: usize,
    /// Timed repetitions per graph (after one warmup run).
    pub iters: usize,
    pub syn_tasks: usize,
    pub qr_tiles: usize,
    pub nb_n: usize,
    pub nb_n_max: usize,
    pub nb_n_task: usize,
    /// Output path for the JSON trajectory (`None` = `bench_out/BENCH_core.json`).
    pub json: Option<std::path::PathBuf>,
}

impl Default for CoreOpts {
    fn default() -> Self {
        Self {
            threads: 1,
            iters: 5,
            syn_tasks: 20_000,
            qr_tiles: 16,
            nb_n: 50_000,
            nb_n_max: 100,
            nb_n_task: 1200,
            json: None,
        }
    }
}

impl CoreOpts {
    pub fn quick() -> Self {
        Self {
            iters: 3,
            syn_tasks: 4_000,
            qr_tiles: 8,
            nb_n: 20_000,
            ..Self::default()
        }
    }
}

/// One graph's measured core overhead.
pub struct CoreRow {
    pub graph: &'static str,
    pub tasks: usize,
    pub dependencies: usize,
    pub threads: usize,
    /// `gettask_ns / tasks_run` of the final empty-kernel run: what the
    /// scheduler itself costs per dispatched task.
    pub dispatch_ns_per_task: f64,
    /// Heap entries scanned per `gettask` probe (hits + misses) across
    /// the timed runs.
    pub mean_scan_len: f64,
    pub elapsed_ms: f64,
}

/// Synthetic core-overhead workload: `n` tasks over 64 flat resources,
/// every 4th task locking one (a few hundred tasks per resource, like
/// the BH cell locks) and a sparse forward dependency chain so the
/// completion path is exercised too. Deterministic.
fn build_synthetic(n: usize, nq: usize) -> Scheduler {
    let mut s = Scheduler::new(SchedConfig::new(nq)).unwrap();
    let rs: Vec<_> = (0..64).map(|i| s.add_resource(None, (i % nq.max(1)) as i32)).collect();
    let mut prev = None;
    for i in 0..n {
        let mut spec = s.task(0u32).cost(1 + (i % 13) as i64);
        if i % 4 == 0 {
            spec = spec.lock(rs[i % 64]);
        }
        if i % 3 == 0 {
            spec = spec.after(prev);
        }
        let t = spec.spawn();
        prev = Some(t);
    }
    s.prepare().unwrap();
    s
}

/// Time `iters` empty-kernel runs of `sched` (one untimed warmup) and
/// fold the run metrics + queue-scan deltas into a [`CoreRow`].
fn measure_core(graph: &'static str, mut sched: Scheduler, opts: &CoreOpts) -> CoreRow {
    let threads = opts.threads.max(1);
    let stats = sched.stats();
    sched.run(threads, |_| {}).unwrap(); // warmup
    let (g0, m0, s0, ..) = sched.queue_stats();
    let mut last: RunMetrics = RunMetrics::default();
    let t0 = Instant::now();
    for _ in 0..opts.iters.max(1) {
        last = sched.run(threads, |_| {}).unwrap();
    }
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3 / opts.iters.max(1) as f64;
    let (g1, m1, s1, ..) = sched.queue_stats();
    let probes = (g1 - g0) + (m1 - m0);
    CoreRow {
        graph,
        tasks: stats.tasks,
        dependencies: stats.dependencies,
        threads,
        dispatch_ns_per_task: last.gettask_ns as f64 / last.tasks_run.max(1) as f64,
        mean_scan_len: (s1 - s0) as f64 / probes.max(1) as f64,
        elapsed_ms,
    }
}

/// `repro bench-core`: empty-kernel dispatch overhead on the synthetic,
/// QR, and Barnes-Hut graphs. Renders a table, writes
/// `core_overhead.csv` and the `BENCH_core.json` trajectory.
pub fn run_core(opts: &CoreOpts) -> (Table, Vec<CoreRow>) {
    let nq = opts.threads.max(1);
    let mut rows = Vec::new();

    rows.push(measure_core("synthetic", build_synthetic(opts.syn_tasks, nq), opts));

    let mut sched = Scheduler::new(SchedConfig::new(nq)).unwrap();
    qr::build_tasks(&mut sched, opts.qr_tiles, opts.qr_tiles);
    sched.prepare().unwrap();
    rows.push(measure_core("qr", sched, opts));

    let tree = nbody::Octree::build(nbody::uniform_cloud(opts.nb_n, 9), opts.nb_n_max);
    let state = nbody::NBodyState::from_tree(tree);
    let mut sched = Scheduler::new(SchedConfig::new(nq)).unwrap();
    nbody::build_tasks(&mut sched, &state, opts.nb_n_task);
    sched.prepare().unwrap();
    rows.push(measure_core("barnes-hut", sched, opts));

    let mut table = Table::new(&[
        "graph", "tasks", "deps", "threads", "dispatch_ns_per_task", "mean_scan_len", "run_ms",
    ]);
    for r in &rows {
        table.row(&[
            r.graph.into(),
            r.tasks.to_string(),
            r.dependencies.to_string(),
            r.threads.to_string(),
            format!("{:.1}", r.dispatch_ns_per_task),
            format!("{:.2}", r.mean_scan_len),
            format!("{:.3}", r.elapsed_ms),
        ]);
    }
    let json_path = opts
        .json
        .clone()
        .unwrap_or_else(|| out_dir().join("BENCH_core.json"));
    // The CSV rides next to the JSON, so a redirected run (e.g. the
    // unit test) never clobbers the real bench_out/ trajectory.
    let csv_path = json_path
        .parent()
        .map(|d| d.join("core_overhead.csv"))
        .unwrap_or_else(|| out_dir().join("core_overhead.csv"));
    let _ = table.write_csv(&csv_path);
    if let Err(e) = write_core_json(&json_path, opts, &rows) {
        eprintln!("could not write {}: {e}", json_path.display());
    } else {
        println!("wrote {}", json_path.display());
    }
    (table, rows)
}

fn write_core_json(
    path: &std::path::Path,
    opts: &CoreOpts,
    rows: &[CoreRow],
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    writeln!(f, "\"bench\": \"core\",")?;
    writeln!(f, "\"threads\": {}, \"iters\": {},", opts.threads.max(1), opts.iters.max(1))?;
    writeln!(f, "\"graphs\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        writeln!(
            f,
            "{{\"graph\": \"{}\", \"tasks\": {}, \"dependencies\": {}, \
             \"dispatch_ns_per_task\": {:.1}, \"mean_gettask_scan_len\": {:.3}, \
             \"run_ms\": {:.3}}}{sep}",
            r.graph, r.tasks, r.dependencies, r.dispatch_ns_per_task, r.mean_scan_len, r.elapsed_ms
        )?;
    }
    writeln!(f, "]")?;
    writeln!(f, "}}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_overhead_small_fraction() {
        let t = run(&OverheadOpts::quick());
        let rendered = t.render();
        assert!(rendered.contains("qr"));
        assert!(rendered.contains("barnes-hut"));
    }

    #[test]
    fn bench_core_emits_rows_and_json() {
        let dir = std::env::temp_dir().join(format!("qs_core_{}", std::process::id()));
        let json = dir.join("BENCH_core.json");
        let opts = CoreOpts {
            iters: 1,
            syn_tasks: 400,
            qr_tiles: 4,
            nb_n: 4_000,
            nb_n_task: 400,
            json: Some(json.clone()),
            ..CoreOpts::quick()
        };
        let (table, rows) = run_core(&opts);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.tasks > 0, "{}: graph must be non-trivial", r.graph);
            assert!(r.dispatch_ns_per_task >= 0.0);
            assert!(r.mean_scan_len >= 0.99, "{}: every probe scans >= 1", r.graph);
        }
        let rendered = table.render();
        assert!(rendered.contains("synthetic") && rendered.contains("barnes-hut"));
        let txt = std::fs::read_to_string(&json).unwrap();
        assert!(txt.contains("\"bench\": \"core\""));
        assert!(txt.contains("dispatch_ns_per_task"));
        let _ = std::fs::remove_dir_all(dir);
    }
}

