//! **Fig. 11** — strong scaling and parallel efficiency of the
//! Barnes-Hut tree-code (paper: 1M particles, n_max=100, n_task=5000),
//! QuickSched vs the Gadget-2-like traditional treewalk with static
//! domain decomposition.
//!
//! Calibration is *measured*, not assumed: ns/interaction for the
//! task-based kernels and for the per-particle walk come from real
//! single-core runs on a smaller cloud; the paper's observed 1.9×
//! single-core cache-efficiency gap emerges from those measurements
//! (recorded in the output). Expected shape: QuickSched scales ~90% to
//! 32 cores then levels off (memory contention, modelled by
//! `nb_cost_model`); Gadget-2 saturates earlier from imbalance + comm.

use crate::coordinator::SchedConfig;
use crate::nbody;

use super::harness::{ms, out_dir, x2, Table, CORE_COUNTS};

pub struct Fig11Opts {
    /// Particle count (paper: 1_000_000).
    pub n: usize,
    pub n_max: usize,
    pub n_task: usize,
    pub reps: usize,
    /// Particle count for real calibration runs.
    pub calib_n: usize,
}

impl Default for Fig11Opts {
    fn default() -> Self {
        Self { n: 1_000_000, n_max: 100, n_task: 5000, reps: 10, calib_n: 30_000 }
    }
}

impl Fig11Opts {
    pub fn quick() -> Self {
        Self { n: 60_000, n_max: 100, n_task: 1200, reps: 2, calib_n: 8_000 }
    }
}

pub struct Fig11Row {
    pub cores: usize,
    pub qs_ns: u64,
    pub gadget_ns: u64,
}

pub fn run(opts: &Fig11Opts) -> (Table, Vec<Fig11Row>) {
    // --- calibration (real runs) ---
    let ns_task = super::calibrate::nb_ns_per_unit(opts.calib_n, opts.n_max, opts.n_task.min(opts.calib_n / 8).max(64));
    let (ns_walk, _) = super::calibrate::walker_ns_per_interaction(opts.calib_n, opts.n_max, 0.5);
    eprintln!(
        "fig11: calibrated task={ns_task:.2} walk={ns_walk:.2} ns/interaction \
         (walk/task = {:.2}x; paper measures 1.9x)",
        ns_walk / ns_task
    );
    let model = nbody::nb_cost_model(ns_task);

    // --- QuickSched scaling (virtual cores over the real task graph) ---
    let cloud = nbody::uniform_cloud(opts.n, 1234);
    let mut rows = Vec::new();
    let mut qs_ns_all = Vec::new();
    for &cores in &CORE_COUNTS {
        let mut total = 0u64;
        for rep in 0..opts.reps {
            let cfg = SchedConfig::new(cores).with_seed(300 + rep as u64);
            let run = nbody::run_sim(
                cloud.clone(),
                opts.n_max,
                opts.n_task,
                cfg,
                cores,
                &model,
            )
            .unwrap();
            total += run.metrics.elapsed_ns;
        }
        qs_ns_all.push(total / opts.reps as u64);
    }

    // --- Gadget-2 baseline: per-particle walk work, statically
    //     decomposed, bulk-synchronous (see nbody::baseline) ---
    let tree = nbody::Octree::build(cloud, opts.n_max);
    let walker = nbody::baseline::TreeWalker::new(&tree, 0.5);
    // Work profile without timing the whole 1M walk twice: count
    // interactions per particle via the walker (cheap relative to sim).
    let (_, work) = walker.solve();
    // Comm calibrated so the baseline's 64-core overhead lands in the
    // few-percent-of-serial range (MPI ghost exchange); see DESIGN.md.
    let comm_alpha = ns_walk * 2.0;
    for (i, &cores) in CORE_COUNTS.iter().enumerate() {
        let gadget_ns = nbody::baseline::bsp_times(&work, cores, ns_walk, comm_alpha);
        rows.push(Fig11Row { cores, qs_ns: qs_ns_all[i], gadget_ns });
    }

    let t1 = rows[0].qs_ns;
    let g1 = rows[0].gadget_ns;
    let mut table = Table::new(&[
        "cores",
        "quicksched_ms",
        "qs_efficiency",
        "gadget_ms",
        "gadget_efficiency",
        "qs_speedup_vs_gadget",
    ]);
    for r in &rows {
        table.row(&[
            r.cores.to_string(),
            ms(r.qs_ns),
            x2(t1 as f64 / r.qs_ns as f64 / r.cores as f64),
            ms(r.gadget_ns),
            x2(g1 as f64 / r.gadget_ns as f64 / r.cores as f64),
            x2(r.gadget_ns as f64 / r.qs_ns as f64),
        ]);
    }
    let _ = table.write_csv(&out_dir().join("fig11_bh_scaling.csv"));
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig11_shape() {
        let (_t, rows) = run(&Fig11Opts { reps: 1, ..Fig11Opts::quick() });
        let t1 = rows[0].qs_ns;
        let t32 = rows[5].qs_ns;
        let speedup32 = t1 as f64 / t32 as f64;
        assert!(speedup32 > 12.0, "BH speedup at 32 cores: {speedup32}");
        // Task-based wins over the BSP walk at full core count (paper: 4x).
        let last = rows.last().unwrap();
        assert!(
            last.gadget_ns > last.qs_ns,
            "gadget {} vs qs {}",
            last.gadget_ns,
            last.qs_ns
        );
        // And already on one core (paper: 1.9x) — ours is whatever the
        // calibration measured, but the direction must hold.
        assert!(rows[0].gadget_ns as f64 > 0.8 * rows[0].qs_ns as f64);
    }
}
