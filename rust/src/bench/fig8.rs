//! **Fig. 8** — strong scaling and parallel efficiency of the tiled QR
//! decomposition (2048×2048, 64×64 tiles), QuickSched vs the
//! dependency-only (OmpSs-like) baseline, 1–64 cores.
//!
//! The paper's machine is simulated by the virtual-time executor with
//! per-unit costs calibrated against a real single-core native run on
//! this machine (see `calibrate.rs`). Expected shape: near-linear
//! scaling to 64 cores (paper: 73% efficiency), with the baseline
//! falling behind at high core counts because it neither prioritizes
//! the DGEQRF critical path nor routes tasks by tile affinity.

use crate::baselines::DepOnlyBuilder;
use crate::coordinator::{KeyPolicy, SchedConfig, Scheduler};
use crate::qr;

use super::harness::{ms, out_dir, x2, Table, CORE_COUNTS};

pub struct Fig8Opts {
    /// Tile-matrix edge (paper: 32 → 2048×2048 at b=64).
    pub tiles: usize,
    /// Tile edge for calibration (paper: 64).
    pub tile: usize,
    /// Repetitions per core count (paper: 10).
    pub reps: usize,
    /// Calibration matrix edge (small real run; cost scales linearly).
    pub calib_tiles: usize,
}

impl Default for Fig8Opts {
    fn default() -> Self {
        Self { tiles: 32, tile: 64, reps: 10, calib_tiles: 8 }
    }
}

impl Fig8Opts {
    /// Reduced-size variant for CI / quick runs.
    pub fn quick() -> Self {
        Self { tiles: 16, tile: 16, reps: 3, calib_tiles: 4 }
    }
}

pub struct Fig8Row {
    pub cores: usize,
    pub qs_ns: u64,
    pub dep_ns: u64,
}

pub fn run(opts: &Fig8Opts) -> (Table, Vec<Fig8Row>) {
    let ns_per_unit = super::calibrate::qr_ns_per_unit(opts.calib_tiles, opts.tile);
    eprintln!(
        "fig8: calibrated {ns_per_unit:.1} ns/unit from {0}x{0} tiles of {1}",
        opts.calib_tiles, opts.tile
    );
    let model = qr::QrCostModel { ns_per_unit };

    let mut rows = Vec::new();
    for &cores in &CORE_COUNTS {
        // QuickSched.
        let mut qs_total = 0u64;
        for rep in 0..opts.reps {
            let cfg = SchedConfig::new(cores).with_seed(100 + rep as u64);
            let run = qr::run_sim(opts.tiles, opts.tiles, cfg, cores, &model).unwrap();
            qs_total += run.metrics.elapsed_ns;
        }
        // Dependency-only baseline over the identical graph.
        let mut dep_total = 0u64;
        for rep in 0..opts.reps {
            let mut b = DepOnlyBuilder::new(cores, 200 + rep as u64).unwrap();
            qr::build_tasks(&mut b, opts.tiles, opts.tiles);
            let mut s = b.finish().unwrap();
            dep_total += s.run_sim(cores, &model).unwrap().elapsed_ns;
        }
        rows.push(Fig8Row {
            cores,
            qs_ns: qs_total / opts.reps as u64,
            dep_ns: dep_total / opts.reps as u64,
        });
    }

    let t1 = rows[0].qs_ns;
    let mut table = Table::new(&[
        "cores",
        "quicksched_ms",
        "speedup",
        "efficiency",
        "dep_only_ms",
        "dep_efficiency",
        "qs_vs_dep",
    ]);
    for r in &rows {
        let speedup = t1 as f64 / r.qs_ns as f64;
        table.row(&[
            r.cores.to_string(),
            ms(r.qs_ns),
            x2(speedup),
            x2(speedup / r.cores as f64),
            ms(r.dep_ns),
            x2(t1 as f64 / r.dep_ns as f64 / r.cores as f64),
            x2(r.dep_ns as f64 / r.qs_ns as f64),
        ]);
    }
    let _ = table.write_csv(&out_dir().join("fig8_qr_scaling.csv"));
    (table, rows)
}

/// Build a QuickSched QR scheduler (exposed for ablation reuse).
pub fn qr_sched(tiles: usize, cores: usize, seed: u64, key: KeyPolicy) -> Scheduler {
    let mut cfg = SchedConfig::new(cores).with_seed(seed);
    cfg.flags.key_policy = key;
    let mut s = Scheduler::new(cfg).unwrap();
    qr::build_tasks(&mut s, tiles, tiles);
    s.prepare().unwrap();
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig8_shape() {
        let (_table, rows) = run(&Fig8Opts { reps: 1, ..Fig8Opts::quick() });
        assert_eq!(rows.len(), CORE_COUNTS.len());
        let t1 = rows[0].qs_ns;
        let t64 = rows.last().unwrap().qs_ns;
        let speedup = t1 as f64 / t64 as f64;
        // 16x16 tiles (816 tasks) on 64 virtual cores: the paper's
        // full-size run achieves 73% efficiency; the small graph bounds
        // what is reachable, but scaling must be substantial.
        assert!(speedup > 8.0, "fig8 speedup {speedup}");
        // QuickSched never loses to the dependency-only baseline.
        for r in &rows {
            assert!(
                r.qs_ns <= r.dep_ns * 21 / 20,
                "cores={}: qs {} vs dep {}",
                r.cores,
                r.qs_ns,
                r.dep_ns
            );
        }
    }
}
