//! Cost-model calibration: tie the virtual-time simulations to *real*
//! single-core measurements of the kernels on this machine, so the sim
//! reproduces the paper's figures with locally-honest absolute scales
//! (see DESIGN.md §Hardware-substitutions).

use crate::coordinator::SchedConfig;
use crate::nbody;
use crate::qr;

/// Measured ns per abstract QR cost unit (units of b³ as in
/// `qr::kernels::cost`): runs a real single-threaded native tiled QR of
/// `mt × mt` tiles of edge `b` and divides measured kernel time by the
/// total graph cost.
pub fn qr_ns_per_unit(mt: usize, b: usize) -> f64 {
    let mat = qr::TiledMatrix::random(b, mt, mt, 0xCAFE);
    let mut sched = crate::coordinator::Scheduler::new(SchedConfig::new(1)).unwrap();
    qr::build_tasks(&mut sched, mt, mt);
    sched.prepare().unwrap();
    let total_cost = sched.total_work();
    let m = sched
        .run_registry(1, &qr::registry(&mat, &qr::NativeBackend))
        .unwrap();
    m.exec_ns as f64 / total_cost as f64
}

/// Measured ns per N-body interaction (the task costs are interaction
/// counts): real single-threaded task-based solve on `n` particles.
pub fn nb_ns_per_unit(n: usize, n_max: usize, n_task: usize) -> f64 {
    let cloud = nbody::uniform_cloud(n, 0xBEEF);
    let tree = nbody::Octree::build(cloud, n_max);
    let state = nbody::NBodyState::from_tree(tree);
    let mut sched = crate::coordinator::Scheduler::new(SchedConfig::new(1)).unwrap();
    nbody::build_tasks(&mut sched, &state, n_task);
    sched.prepare().unwrap();
    let total_cost = sched.total_work();
    let m = sched.run_registry(1, &nbody::registry(&state)).unwrap();
    m.exec_ns as f64 / total_cost as f64
}

/// Measured ns per interaction of the *traditional per-particle
/// treewalk* (the Gadget-2 stand-in). Because the walk chases pointers
/// per particle instead of streaming contiguous leaves, this comes out
/// slower than [`nb_ns_per_unit`] — the paper measures 1.9× on one
/// core; we measure ours instead of assuming it.
pub fn walker_ns_per_interaction(n: usize, n_max: usize, theta: f64) -> (f64, Vec<usize>) {
    let cloud = nbody::uniform_cloud(n, 0xBEEF);
    let tree = nbody::Octree::build(cloud, n_max);
    let walker = nbody::baseline::TreeWalker::new(&tree, theta);
    let t0 = std::time::Instant::now();
    let (_, work) = walker.solve();
    let ns = t0.elapsed().as_nanos() as f64;
    let total: usize = work.iter().sum();
    (ns / total.max(1) as f64, work)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_calibration_positive() {
        let ns = qr_ns_per_unit(4, 8);
        assert!(ns > 0.0 && ns.is_finite(), "{ns}");
    }

    #[test]
    fn nb_calibration_positive() {
        let ns = nb_ns_per_unit(2000, 64, 300);
        assert!(ns > 0.0 && ns.is_finite(), "{ns}");
    }

    #[test]
    fn walker_calibration() {
        let (ns, work) = walker_ns_per_interaction(2000, 64, 0.5);
        assert!(ns > 0.0 && ns.is_finite());
        assert_eq!(work.len(), 2000);
        assert!(work.iter().all(|&w| w > 0));
    }
}
