//! Task queues (paper §3.3).
//!
//! Each queue stores ready tasks in a spin-locked array organized as a
//! binary max-heap on the task's scheduling key (the critical-path weight
//! by default). `get` traverses the heap array *as if sorted* — the first
//! entry is the true maximum, the rest only loosely ordered — and returns
//! the first task whose resources can all be locked. The paper argues (and
//! §4 confirms) this loose order is sufficient in practice, while keeping
//! insertion and removal at O(log n).
//!
//! Two queue flavors share the same heap + spin-lock machinery:
//!
//! * [`Queue`] — the paper's per-scheduler queue. Entries are plain
//!   `(key, task)` pairs and `get` resolves conflicts itself against the
//!   owning scheduler's compiled graph and resource table.
//! * [`TaggedQueue`] — a *cross-job* shard used by the server's shared
//!   dispatch layer (`server::shard`). Entries additionally carry an
//!   opaque 64-bit tag naming the job they belong to; `get` delegates
//!   the "can this entry be taken?" decision to a caller closure, since
//!   each entry's tasks and resources live in a different scheduler.
//!   Stale entries (their job is gone) are purged in place during scans.
//!
//! **Layout (§Perf opt E).** The spin-lock word, the `total_key`
//! accumulator, and every [`QueueStats`] counter sit on their own cache
//! line ([`CachePadded`]): `mutex_spins`/`lock_failures` are bumped from
//! every worker, and before padding a stats bump on one queue could
//! evict the *lock word* of the same or a neighboring queue from other
//! cores' caches. `total_key` is additionally maintained *under* the
//! already-held queue lock as a plain load + `Release` store — only
//! lock holders write it, so the enqueue hot path pays no atomic RMW
//! for it.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::compiled::CompiledGraph;
use super::resource::{ResId, ResTable};
use super::task::TaskId;
use crate::util::pad::CachePadded;

/// One heap entry: scheduling key + task id. Keys are compared first; ties
/// broken by task id for determinism.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: i64,
    pub tid: TaskId,
}

impl Entry {
    #[inline]
    fn ge(&self, other: &Entry) -> bool {
        (self.key, other.tid.0) >= (other.key, self.tid.0)
    }
}

/// Contention / scan statistics, used by the Fig. 13 overhead accounting.
/// Each counter is cache-line-padded: they are bumped from every worker
/// on every probe, and must not false-share with each other or with the
/// queue's lock word.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// Successful `get` calls.
    pub gets: CachePadded<AtomicU64>,
    /// `get` calls that returned nothing (empty or all-conflicted).
    pub misses: CachePadded<AtomicU64>,
    /// Tasks scanned across all `get` calls.
    pub scanned: CachePadded<AtomicU64>,
    /// Resource lock attempts that failed during scans.
    pub lock_failures: CachePadded<AtomicU64>,
    /// Spins while acquiring the queue mutex.
    pub mutex_spins: CachePadded<AtomicU64>,
    /// Stale entries discarded during scans ([`TaggedQueue`] only:
    /// entries whose owning job already left the slot table).
    pub purged: CachePadded<AtomicU64>,
}

impl QueueStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.gets.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.scanned.load(Ordering::Relaxed),
            self.lock_failures.load(Ordering::Relaxed),
            self.mutex_spins.load(Ordering::Relaxed),
        )
    }
}

/// A spin-locked max-heap task queue (paper §3.3 `struct queue`).
///
/// The paper deliberately protects the whole queue with a single lock:
/// with one queue per thread, contention arises only from work stealing,
/// which is rare (validated in §4 and by `benches/micro_scheduler.rs`).
pub struct Queue {
    /// 0 = free, 1 = locked. Padded: a stats or `total_key` write must
    /// never bounce the line other workers are CAS-ing on.
    lock: CachePadded<AtomicUsize>,
    /// Heap storage; guarded by `lock`.
    heap: UnsafeCell<Vec<Entry>>,
    /// Sum of keys currently queued (for weight-aware stealing, §5 ext).
    /// Written only while `lock` is held (plain load + `Release` store —
    /// no RMW on the put/get hot paths); read racily by stealers.
    total_key: CachePadded<AtomicU64>,
    pub stats: QueueStats,
}

// SAFETY: `heap` is only touched while `lock` is held (acquire/release CAS).
unsafe impl Sync for Queue {}
unsafe impl Send for Queue {}

impl Queue {
    pub fn new(capacity: usize) -> Self {
        Self {
            lock: CachePadded::new(AtomicUsize::new(0)),
            heap: UnsafeCell::new(Vec::with_capacity(capacity)),
            total_key: CachePadded::new(AtomicU64::new(0)),
            stats: QueueStats::default(),
        }
    }

    #[inline]
    fn acquire(&self) {
        let mut spins = 0u64;
        while self
            .lock
            .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            spins += 1;
            std::hint::spin_loop();
        }
        if spins > 0 {
            self.stats.mutex_spins.fetch_add(spins, Ordering::Relaxed);
        }
    }

    #[inline]
    fn release(&self) {
        self.lock.store(0, Ordering::Release);
    }

    /// Adjust `total_key` by `delta`. Must be called with the queue lock
    /// held: exclusivity is what makes the plain load/store pair sound.
    #[inline]
    fn total_key_add_locked(&self, delta: i64) {
        let cur = self.total_key.load(Ordering::Relaxed);
        self.total_key
            .store(cur.wrapping_add(delta as u64), Ordering::Release);
    }

    /// Number of queued tasks (racy snapshot).
    pub fn len(&self) -> usize {
        self.acquire();
        let n = unsafe { (*self.heap.get()).len() };
        self.release();
        n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of queued keys (racy snapshot; used by weight-aware stealing).
    #[inline]
    pub fn total_key(&self) -> u64 {
        self.total_key.load(Ordering::Relaxed)
    }

    /// `queue_put` (§3.3): append + bubble-up under the queue lock.
    pub fn put(&self, key: i64, tid: TaskId) {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        heap.push(Entry { key, tid });
        let last = heap.len() - 1;
        sift_up(heap, last);
        self.total_key_add_locked(key.max(0));
        self.release();
    }

    /// `queue_get` (§3.3): scan the heap array in index order, try to lock
    /// every resource of each candidate (already id-sorted at freeze
    /// time to dodge the dining-philosophers deadlock); the first fully
    /// lockable task is removed from the heap and returned *with its locks
    /// held*. Returns `None` if the queue is empty or everything conflicts.
    ///
    /// The candidate lock sets are spans of the compiled graph's shared
    /// adjacency arena: the whole scan walks two flat arrays (heap +
    /// arena) instead of chasing a `Vec` allocation per candidate.
    pub fn get(&self, g: &CompiledGraph, res: &ResTable) -> Option<TaskId> {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let mut found: Option<usize> = None;
        let mut scanned = 0u64;
        let mut lock_failures = 0u64;
        // Resources that already failed a try_lock during *this* scan.
        // A resource locked by someone else stays locked for the whole
        // scan (only `complete` unlocks, and that cannot release a lock
        // we watched fail and then matter again within this pass), so
        // skipping repeat offenders turns the pathological
        // "many queued tasks contending one resource" scan from
        // O(n · CAS) into O(n) reads. (§Perf opt A; see EXPERIMENTS.md.)
        let mut failed = [u32::MAX; 8];
        let mut n_failed = 0usize;
        'scan: for k in 0..heap.len() {
            scanned += 1;
            let locks = g.lock_ids(heap[k].tid.idx());
            if n_failed > 0 && locks.iter().any(|r| failed[..n_failed].contains(r)) {
                continue 'scan;
            }
            for (j, &rid) in locks.iter().enumerate() {
                if !res.try_lock(ResId(rid)) {
                    lock_failures += 1;
                    if n_failed < failed.len() {
                        failed[n_failed] = rid;
                        n_failed += 1;
                    }
                    // Roll back the prefix of locks we did get.
                    for &r_prev in &locks[..j] {
                        res.unlock(ResId(r_prev));
                    }
                    continue 'scan;
                }
            }
            found = Some(k);
            break;
        }
        let out = found.map(|k| {
            let entry = heap[k];
            let last = heap.pop().unwrap();
            if k < heap.len() {
                heap[k] = last;
                // Replacing an arbitrary element can violate heap order in
                // either direction; restore both ways.
                let k2 = sift_up(heap, k);
                sift_down(heap, k2);
            }
            self.total_key_add_locked(-entry.key.max(0));
            entry.tid
        });
        self.release();
        self.stats.scanned.fetch_add(scanned, Ordering::Relaxed);
        self.stats
            .lock_failures
            .fetch_add(lock_failures, Ordering::Relaxed);
        match out {
            Some(_) => self.stats.gets.fetch_add(1, Ordering::Relaxed),
            None => self.stats.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Pop the maximum entry unconditionally (no resource locking). Used by
    /// the dependency-only baseline and by tests.
    pub fn pop_max(&self) -> Option<Entry> {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let out = if heap.is_empty() {
            None
        } else {
            let top = heap[0];
            let last = heap.pop().unwrap();
            if !heap.is_empty() {
                heap[0] = last;
                sift_down(heap, 0);
            }
            self.total_key_add_locked(-top.key.max(0));
            Some(top)
        };
        self.release();
        out
    }

    /// Snapshot of queued entries in heap-array order (diagnostics/tests).
    pub fn snapshot(&self) -> Vec<Entry> {
        self.acquire();
        let v = unsafe { (*self.heap.get()).clone() };
        self.release();
        v
    }

    /// Clear all entries (scheduler reset).
    pub fn clear(&self) {
        self.acquire();
        unsafe { (*self.heap.get()).clear() };
        self.total_key.store(0, Ordering::Release);
        self.release();
    }

    /// Verify the max-heap invariant (tests only).
    pub fn check_heap(&self) -> bool {
        let v = self.snapshot();
        (1..v.len()).all(|k| v[(k - 1) / 2].ge(&v[k]))
    }
}

#[inline]
fn sift_up_by<E, F>(heap: &mut [E], mut k: usize, ge: F) -> usize
where
    E: Copy + PartialEq,
    F: Fn(&E, &E) -> bool,
{
    while k > 0 {
        let parent = (k - 1) / 2;
        if ge(&heap[k], &heap[parent]) && heap[k] != heap[parent] {
            heap.swap(k, parent);
            k = parent;
        } else {
            break;
        }
    }
    k
}

#[inline]
fn sift_down_by<E, F>(heap: &mut [E], mut k: usize, ge: F)
where
    E: Copy + PartialEq,
    F: Fn(&E, &E) -> bool,
{
    let n = heap.len();
    loop {
        let l = 2 * k + 1;
        let r = 2 * k + 2;
        let mut m = k;
        if l < n && ge(&heap[l], &heap[m]) && heap[l] != heap[m] {
            m = l;
        }
        if r < n && ge(&heap[r], &heap[m]) && heap[r] != heap[m] {
            m = r;
        }
        if m == k {
            break;
        }
        heap.swap(k, m);
        k = m;
    }
}

#[inline]
fn sift_up(heap: &mut [Entry], k: usize) -> usize {
    sift_up_by(heap, k, Entry::ge)
}

#[inline]
fn sift_down(heap: &mut [Entry], k: usize) {
    sift_down_by(heap, k, Entry::ge)
}

// ----------------------------------------------------------------------
// Cross-job tagged shard queue
// ----------------------------------------------------------------------

/// One [`TaggedQueue`] heap entry: scheduling key (the task's
/// critical-path weight), an opaque job tag assigned by the shard layer
/// (`server::shard` packs a slot index and a generation into it), and the
/// task id *within that job's scheduler*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaggedEntry {
    pub key: i64,
    pub tag: u64,
    pub tid: TaskId,
}

impl TaggedEntry {
    /// Max-heap order: higher key first; ties broken by lower tag then
    /// lower task id for determinism.
    #[inline]
    fn ge(&self, other: &TaggedEntry) -> bool {
        (self.key, other.tag, other.tid.0) >= (other.key, self.tag, self.tid.0)
    }
}

/// Outcome of the caller's take-decision for one scanned [`TaggedEntry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Take {
    /// The entry's task was acquired (its resources are locked); remove
    /// the entry and stop the scan.
    Taken,
    /// The task exists but cannot run now (resource conflict); keep the
    /// entry, keep scanning.
    Busy,
    /// The tag no longer resolves to a live job; discard the entry and
    /// keep scanning.
    Stale,
}

/// A spin-locked max-heap of [`TaggedEntry`]s — one *shard* of the
/// server's shared cross-job ready-queue layer.
///
/// The structure is the paper's §3.3 queue with one twist: because its
/// entries belong to many different jobs (each with its own task and
/// resource tables), the conflict check in `get` is delegated to the
/// caller through a closure instead of being performed against a single
/// scheduler. The heap scan keeps the paper's loose
/// highest-key-first order. Like [`Queue`], the shard's spin-lock word
/// and statistics counters are cache-line-padded — shards are probed by
/// every worker, so a stats bump on one must not evict another core's
/// view of the lock word.
///
/// ```
/// use quicksched::coordinator::queue::{TaggedQueue, Take};
/// use quicksched::coordinator::TaskId;
///
/// let q = TaggedQueue::new(4);
/// q.put(5, 7, TaskId(0));
/// q.put(9, 7, TaskId(1));
/// // The closure decides per entry; here everything is acquirable.
/// assert_eq!(q.get(|_tag, _tid| Take::Taken), Some((7, TaskId(1))));
/// assert_eq!(q.get(|_tag, _tid| Take::Taken), Some((7, TaskId(0))));
/// assert_eq!(q.get(|_tag, _tid| Take::Taken), None);
/// ```
pub struct TaggedQueue {
    /// 0 = free, 1 = locked (padded, like `Queue`'s lock word).
    lock: CachePadded<AtomicUsize>,
    /// Heap storage; guarded by `lock`.
    heap: UnsafeCell<Vec<TaggedEntry>>,
    pub stats: QueueStats,
}

// SAFETY: `heap` is only touched while `lock` is held (acquire/release CAS).
unsafe impl Sync for TaggedQueue {}
unsafe impl Send for TaggedQueue {}

impl TaggedQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            lock: CachePadded::new(AtomicUsize::new(0)),
            heap: UnsafeCell::new(Vec::with_capacity(capacity)),
            stats: QueueStats::default(),
        }
    }

    #[inline]
    fn acquire(&self) {
        let mut spins = 0u64;
        while self
            .lock
            .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            spins += 1;
            std::hint::spin_loop();
        }
        if spins > 0 {
            self.stats.mutex_spins.fetch_add(spins, Ordering::Relaxed);
        }
    }

    #[inline]
    fn release(&self) {
        self.lock.store(0, Ordering::Release);
    }

    /// Number of queued entries (racy snapshot).
    pub fn len(&self) -> usize {
        self.acquire();
        let n = unsafe { (*self.heap.get()).len() };
        self.release();
        n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert an entry (append + bubble-up under the shard lock).
    pub fn put(&self, key: i64, tag: u64, tid: TaskId) {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        heap.push(TaggedEntry { key, tag, tid });
        let last = heap.len() - 1;
        sift_up_by(heap, last, TaggedEntry::ge);
        self.release();
    }

    /// Remove the entry at `k`, restoring heap order both ways (the
    /// swapped-in tail element may need to move up *or* down).
    fn remove_at(heap: &mut Vec<TaggedEntry>, k: usize) {
        let last = heap.pop().expect("remove_at on empty heap");
        if k < heap.len() {
            heap[k] = last;
            let k2 = sift_up_by(heap, k, TaggedEntry::ge);
            sift_down_by(heap, k2, TaggedEntry::ge);
        }
    }

    /// Scan the heap array in index order (loose highest-key-first, as in
    /// the paper) and offer each entry to `take`, which resolves the tag
    /// to its job and attempts the task's resource locks. The first
    /// [`Take::Taken`] entry is removed and returned; [`Take::Stale`]
    /// entries are discarded in place; [`Take::Busy`] entries stay.
    ///
    /// `take` runs under the shard spin-lock: it must be non-blocking
    /// (resource `try_lock` and a short slot-table mutex are fine; never
    /// another shard's lock).
    pub fn get<F: FnMut(u64, TaskId) -> Take>(&self, mut take: F) -> Option<(u64, TaskId)> {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let mut scanned = 0u64;
        let mut busy = 0u64;
        let mut purged = 0u64;
        let mut out = None;
        let mut k = 0usize;
        while k < heap.len() {
            scanned += 1;
            let e = heap[k];
            match take(e.tag, e.tid) {
                Take::Busy => {
                    busy += 1;
                    k += 1;
                }
                Take::Stale => {
                    purged += 1;
                    // The tail swaps into `k`: re-examine the same index.
                    Self::remove_at(heap, k);
                }
                Take::Taken => {
                    Self::remove_at(heap, k);
                    out = Some((e.tag, e.tid));
                    break;
                }
            }
        }
        self.release();
        self.stats.scanned.fetch_add(scanned, Ordering::Relaxed);
        if busy > 0 {
            self.stats.lock_failures.fetch_add(busy, Ordering::Relaxed);
        }
        if purged > 0 {
            self.stats.purged.fetch_add(purged, Ordering::Relaxed);
        }
        match out {
            Some(_) => self.stats.gets.fetch_add(1, Ordering::Relaxed),
            None => self.stats.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Drop every entry, returning how many were queued. Shutdown /
    /// test helper; live serving purges stale entries lazily in `get`.
    pub fn clear(&self) -> usize {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let n = heap.len();
        heap.clear();
        self.release();
        n
    }

    /// Verify the max-heap invariant (tests only).
    pub fn check_heap(&self) -> bool {
        self.acquire();
        let v = unsafe { (*self.heap.get()).clone() };
        self.release();
        (1..v.len()).all(|k| v[(k - 1) / 2].ge(&v[k]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::OWNER_NONE;
    use crate::coordinator::task::{Task, TaskFlags};

    fn mk_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| Task::new(i as u32, TaskFlags::default(), vec![], 1))
            .collect()
    }

    fn freeze(tasks: &[Task], res: &ResTable) -> CompiledGraph {
        CompiledGraph::freeze(tasks, res).unwrap()
    }

    #[test]
    fn put_preserves_heap() {
        let q = Queue::new(8);
        for (i, key) in [5i64, 1, 9, 3, 9, 2, 8].iter().enumerate() {
            q.put(*key, TaskId(i as u32));
            assert!(q.check_heap(), "heap broken after put {i}");
        }
        assert_eq!(q.len(), 7);
    }

    #[test]
    fn pop_max_is_descending() {
        let q = Queue::new(8);
        let keys = [3i64, 11, 7, 2, 19, 5];
        for (i, k) in keys.iter().enumerate() {
            q.put(*k, TaskId(i as u32));
        }
        let mut out = Vec::new();
        while let Some(e) = q.pop_max() {
            out.push(e.key);
        }
        let mut sorted = keys.to_vec();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(out, sorted);
    }

    #[test]
    fn get_returns_max_when_unconflicted() {
        let res = ResTable::new();
        let g = freeze(&mk_tasks(3), &res);
        let q = Queue::new(4);
        q.put(10, TaskId(0));
        q.put(30, TaskId(1));
        q.put(20, TaskId(2));
        assert_eq!(q.get(&g, &res), Some(TaskId(1)));
        assert_eq!(q.get(&g, &res), Some(TaskId(2)));
        assert_eq!(q.get(&g, &res), Some(TaskId(0)));
        assert_eq!(q.get(&g, &res), None);
    }

    #[test]
    fn get_skips_conflicted_tasks() {
        let mut res = ResTable::new();
        let shared = res.add(None, OWNER_NONE);
        let free = res.add(None, OWNER_NONE);
        let mut tasks = mk_tasks(2);
        tasks[0].add_lock(shared); // heavier task, conflicted
        tasks[1].add_lock(free);
        let g = freeze(&tasks, &res);
        let q = Queue::new(4);
        q.put(100, TaskId(0));
        q.put(1, TaskId(1));
        // Pre-lock the shared resource: task 0 must be skipped.
        assert!(res.try_lock(shared));
        assert_eq!(q.get(&g, &res), Some(TaskId(1)));
        assert!(res.get(free).is_locked(), "returned task keeps its locks");
        res.unlock(free);
        // Task 0 still queued and blocked.
        assert_eq!(q.get(&g, &res), None);
        assert_eq!(q.len(), 1);
        res.unlock(shared);
        assert_eq!(q.get(&g, &res), Some(TaskId(0)));
        res.unlock(shared);
        assert!(res.all_quiescent());
    }

    #[test]
    fn get_rolls_back_partial_locks() {
        let mut res = ResTable::new();
        let a = res.add(None, OWNER_NONE);
        let b = res.add(None, OWNER_NONE);
        let mut tasks = mk_tasks(1);
        tasks[0].add_lock(a);
        tasks[0].add_lock(b);
        let g = freeze(&tasks, &res);
        let q = Queue::new(2);
        q.put(1, TaskId(0));
        assert!(res.try_lock(b)); // second lock will fail
        assert_eq!(q.get(&g, &res), None);
        assert!(!res.get(a).is_locked(), "partial lock on `a` leaked");
        res.unlock(b);
        assert_eq!(q.get(&g, &res), Some(TaskId(0)));
        res.unlock(a);
        res.unlock(b);
        assert!(res.all_quiescent());
    }

    #[test]
    fn total_key_tracks_contents() {
        let res = ResTable::new();
        let g = freeze(&mk_tasks(2), &res);
        let q = Queue::new(2);
        q.put(5, TaskId(0));
        q.put(7, TaskId(1));
        assert_eq!(q.total_key(), 12);
        q.get(&g, &res);
        assert_eq!(q.total_key(), 5);
        q.clear();
        assert_eq!(q.total_key(), 0);
    }

    #[test]
    fn stats_count_misses() {
        let res = ResTable::new();
        let g = freeze(&mk_tasks(1), &res);
        let q = Queue::new(1);
        assert_eq!(q.get(&g, &res), None);
        let (gets, misses, ..) = q.stats.snapshot();
        assert_eq!((gets, misses), (0, 1));
    }

    #[test]
    fn tagged_queue_orders_by_key() {
        let q = TaggedQueue::new(8);
        for (i, key) in [4i64, 9, 1, 7].iter().enumerate() {
            q.put(*key, 1, TaskId(i as u32));
            assert!(q.check_heap(), "tagged heap broken after put {i}");
        }
        let mut keys = Vec::new();
        while let Some((tag, tid)) = q.get(|_, _| Take::Taken) {
            assert_eq!(tag, 1);
            keys.push([4i64, 9, 1, 7][tid.idx()]);
        }
        assert_eq!(keys, vec![9, 7, 4, 1]);
        let (gets, misses, ..) = q.stats.snapshot();
        assert_eq!((gets, misses), (4, 1));
    }

    #[test]
    fn tagged_queue_skips_busy_purges_stale() {
        let q = TaggedQueue::new(8);
        q.put(30, 100, TaskId(0)); // stale job
        q.put(20, 200, TaskId(1)); // busy task
        q.put(10, 300, TaskId(2)); // acquirable
        let got = q.get(|tag, _| match tag {
            100 => Take::Stale,
            200 => Take::Busy,
            _ => Take::Taken,
        });
        assert_eq!(got, Some((300, TaskId(2))));
        // The stale entry is gone, the busy one survived.
        assert_eq!(q.len(), 1);
        assert_eq!(q.stats.purged.load(Ordering::Relaxed), 1);
        assert_eq!(q.get(|_, _| Take::Busy), None);
        assert_eq!(q.clear(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn tagged_queue_all_stale_drains_to_empty() {
        let q = TaggedQueue::new(8);
        for i in 0..5 {
            q.put(i as i64, 9, TaskId(i));
        }
        assert_eq!(q.get(|_, _| Take::Stale), None);
        assert!(q.is_empty(), "every stale entry must be purged in one scan");
        assert_eq!(q.stats.purged.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn concurrent_put_get_loses_nothing() {
        use std::sync::atomic::AtomicU64;
        use std::sync::Arc;
        let n = 4000usize;
        let res = Arc::new(ResTable::new());
        let g = Arc::new(freeze(&mk_tasks(n), &res));
        let q = Arc::new(Queue::new(n));
        let got = Arc::new(AtomicU64::new(0));
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in (p..n).step_by(2) {
                        q.put(i as i64, TaskId(i as u32));
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                let g = Arc::clone(&g);
                let res = Arc::clone(&res);
                let got = Arc::clone(&got);
                std::thread::spawn(move || {
                    let mut local = 0u64;
                    let mut idle = 0;
                    while idle < 10_000 {
                        match q.get(&g, &res) {
                            Some(_) => {
                                local += 1;
                                idle = 0;
                            }
                            None => {
                                idle += 1;
                                // Let the producers run (single-core CI).
                                std::thread::yield_now();
                            }
                        }
                    }
                    got.fetch_add(local, Ordering::Relaxed);
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(got.load(Ordering::Relaxed), n as u64);
        assert!(q.is_empty());
    }
}
