//! Task queues (paper §3.3).
//!
//! Each queue stores ready tasks in a spin-locked array organized as a
//! binary max-heap on the task's scheduling key (the critical-path weight
//! by default). `get` traverses the heap array *as if sorted* — the first
//! entry is the true maximum, the rest only loosely ordered — and returns
//! the first task whose resources can all be locked. The paper argues (and
//! §4 confirms) this loose order is sufficient in practice, while keeping
//! insertion and removal at O(log n).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::resource::{ResId, ResTable};
use super::task::{Task, TaskId};

/// One heap entry: scheduling key + task id. Keys are compared first; ties
/// broken by task id for determinism.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: i64,
    pub tid: TaskId,
}

impl Entry {
    #[inline]
    fn ge(&self, other: &Entry) -> bool {
        (self.key, other.tid.0) >= (other.key, self.tid.0)
    }
}

/// Contention / scan statistics, used by the Fig. 13 overhead accounting.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// Successful `get` calls.
    pub gets: AtomicU64,
    /// `get` calls that returned nothing (empty or all-conflicted).
    pub misses: AtomicU64,
    /// Tasks scanned across all `get` calls.
    pub scanned: AtomicU64,
    /// Resource lock attempts that failed during scans.
    pub lock_failures: AtomicU64,
    /// Spins while acquiring the queue mutex.
    pub mutex_spins: AtomicU64,
}

impl QueueStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.gets.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.scanned.load(Ordering::Relaxed),
            self.lock_failures.load(Ordering::Relaxed),
            self.mutex_spins.load(Ordering::Relaxed),
        )
    }
}

/// A spin-locked max-heap task queue (paper §3.3 `struct queue`).
///
/// The paper deliberately protects the whole queue with a single lock:
/// with one queue per thread, contention arises only from work stealing,
/// which is rare (validated in §4 and by `benches/micro_scheduler.rs`).
pub struct Queue {
    /// 0 = free, 1 = locked.
    lock: AtomicUsize,
    /// Heap storage; guarded by `lock`.
    heap: UnsafeCell<Vec<Entry>>,
    /// Sum of keys currently queued (for weight-aware stealing, §5 ext).
    total_key: AtomicU64,
    pub stats: QueueStats,
}

// SAFETY: `heap` is only touched while `lock` is held (acquire/release CAS).
unsafe impl Sync for Queue {}
unsafe impl Send for Queue {}

impl Queue {
    pub fn new(capacity: usize) -> Self {
        Self {
            lock: AtomicUsize::new(0),
            heap: UnsafeCell::new(Vec::with_capacity(capacity)),
            total_key: AtomicU64::new(0),
            stats: QueueStats::default(),
        }
    }

    #[inline]
    fn acquire(&self) {
        let mut spins = 0u64;
        while self
            .lock
            .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            spins += 1;
            std::hint::spin_loop();
        }
        if spins > 0 {
            self.stats.mutex_spins.fetch_add(spins, Ordering::Relaxed);
        }
    }

    #[inline]
    fn release(&self) {
        self.lock.store(0, Ordering::Release);
    }

    /// Number of queued tasks (racy snapshot).
    pub fn len(&self) -> usize {
        self.acquire();
        let n = unsafe { (*self.heap.get()).len() };
        self.release();
        n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of queued keys (racy snapshot; used by weight-aware stealing).
    #[inline]
    pub fn total_key(&self) -> u64 {
        self.total_key.load(Ordering::Relaxed)
    }

    /// `queue_put` (§3.3): append + bubble-up under the queue lock.
    pub fn put(&self, key: i64, tid: TaskId) {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        heap.push(Entry { key, tid });
        let last = heap.len() - 1;
        sift_up(heap, last);
        self.release();
        self.total_key.fetch_add(key.max(0) as u64, Ordering::Relaxed);
    }

    /// `queue_get` (§3.3): scan the heap array in index order, try to lock
    /// every resource of each candidate (already sorted by id at prepare
    /// time to dodge the dining-philosophers deadlock); the first fully
    /// lockable task is removed from the heap and returned *with its locks
    /// held*. Returns `None` if the queue is empty or everything conflicts.
    pub fn get(&self, tasks: &[Task], res: &ResTable) -> Option<TaskId> {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let mut found: Option<usize> = None;
        let mut scanned = 0u64;
        let mut lock_failures = 0u64;
        // Resources that already failed a try_lock during *this* scan.
        // A resource locked by someone else stays locked for the whole
        // scan (only `complete` unlocks, and that cannot release a lock
        // we watched fail and then matter again within this pass), so
        // skipping repeat offenders turns the pathological
        // "many queued tasks contending one resource" scan from
        // O(n · CAS) into O(n) reads. (§Perf opt A; see EXPERIMENTS.md.)
        let mut failed = [ResId(u32::MAX); 8];
        let mut n_failed = 0usize;
        'scan: for k in 0..heap.len() {
            scanned += 1;
            let t = &tasks[heap[k].tid.idx()];
            if n_failed > 0
                && t.locks.iter().any(|r| failed[..n_failed].contains(r))
            {
                continue 'scan;
            }
            for (j, &rid) in t.locks.iter().enumerate() {
                if !res.try_lock(rid) {
                    lock_failures += 1;
                    if n_failed < failed.len() {
                        failed[n_failed] = rid;
                        n_failed += 1;
                    }
                    // Roll back the prefix of locks we did get.
                    for &r_prev in &t.locks[..j] {
                        res.unlock(r_prev);
                    }
                    continue 'scan;
                }
            }
            found = Some(k);
            break;
        }
        let out = found.map(|k| {
            let entry = heap[k];
            let last = heap.pop().unwrap();
            if k < heap.len() {
                heap[k] = last;
                // Replacing an arbitrary element can violate heap order in
                // either direction; restore both ways.
                let k2 = sift_up(heap, k);
                sift_down(heap, k2);
            }
            self.total_key
                .fetch_sub(entry.key.max(0) as u64, Ordering::Relaxed);
            entry.tid
        });
        self.release();
        self.stats.scanned.fetch_add(scanned, Ordering::Relaxed);
        self.stats
            .lock_failures
            .fetch_add(lock_failures, Ordering::Relaxed);
        match out {
            Some(_) => self.stats.gets.fetch_add(1, Ordering::Relaxed),
            None => self.stats.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Pop the maximum entry unconditionally (no resource locking). Used by
    /// the dependency-only baseline and by tests.
    pub fn pop_max(&self) -> Option<Entry> {
        self.acquire();
        let heap = unsafe { &mut *self.heap.get() };
        let out = if heap.is_empty() {
            None
        } else {
            let top = heap[0];
            let last = heap.pop().unwrap();
            if !heap.is_empty() {
                heap[0] = last;
                sift_down(heap, 0);
            }
            self.total_key
                .fetch_sub(top.key.max(0) as u64, Ordering::Relaxed);
            Some(top)
        };
        self.release();
        out
    }

    /// Snapshot of queued entries in heap-array order (diagnostics/tests).
    pub fn snapshot(&self) -> Vec<Entry> {
        self.acquire();
        let v = unsafe { (*self.heap.get()).clone() };
        self.release();
        v
    }

    /// Clear all entries (scheduler reset).
    pub fn clear(&self) {
        self.acquire();
        unsafe { (*self.heap.get()).clear() };
        self.release();
        self.total_key.store(0, Ordering::Relaxed);
    }

    /// Verify the max-heap invariant (tests only).
    pub fn check_heap(&self) -> bool {
        let v = self.snapshot();
        (1..v.len()).all(|k| v[(k - 1) / 2].ge(&v[k]))
    }
}

#[inline]
fn sift_up(heap: &mut [Entry], mut k: usize) -> usize {
    while k > 0 {
        let parent = (k - 1) / 2;
        if heap[k].ge(&heap[parent]) && heap[k] != heap[parent] {
            heap.swap(k, parent);
            k = parent;
        } else {
            break;
        }
    }
    k
}

#[inline]
fn sift_down(heap: &mut [Entry], mut k: usize) {
    let n = heap.len();
    loop {
        let l = 2 * k + 1;
        let r = 2 * k + 2;
        let mut m = k;
        if l < n && heap[l].ge(&heap[m]) && heap[l] != heap[m] {
            m = l;
        }
        if r < n && heap[r].ge(&heap[m]) && heap[r] != heap[m] {
            m = r;
        }
        if m == k {
            break;
        }
        heap.swap(k, m);
        k = m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::OWNER_NONE;
    use crate::coordinator::task::TaskFlags;

    fn mk_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| Task::new(i as u32, TaskFlags::default(), vec![], 1))
            .collect()
    }

    #[test]
    fn put_preserves_heap() {
        let q = Queue::new(8);
        for (i, key) in [5i64, 1, 9, 3, 9, 2, 8].iter().enumerate() {
            q.put(*key, TaskId(i as u32));
            assert!(q.check_heap(), "heap broken after put {i}");
        }
        assert_eq!(q.len(), 7);
    }

    #[test]
    fn pop_max_is_descending() {
        let q = Queue::new(8);
        let keys = [3i64, 11, 7, 2, 19, 5];
        for (i, k) in keys.iter().enumerate() {
            q.put(*k, TaskId(i as u32));
        }
        let mut out = Vec::new();
        while let Some(e) = q.pop_max() {
            out.push(e.key);
        }
        let mut sorted = keys.to_vec();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(out, sorted);
    }

    #[test]
    fn get_returns_max_when_unconflicted() {
        let tasks = mk_tasks(3);
        let res = ResTable::new();
        let q = Queue::new(4);
        q.put(10, TaskId(0));
        q.put(30, TaskId(1));
        q.put(20, TaskId(2));
        assert_eq!(q.get(&tasks, &res), Some(TaskId(1)));
        assert_eq!(q.get(&tasks, &res), Some(TaskId(2)));
        assert_eq!(q.get(&tasks, &res), Some(TaskId(0)));
        assert_eq!(q.get(&tasks, &res), None);
    }

    #[test]
    fn get_skips_conflicted_tasks() {
        let mut res = ResTable::new();
        let shared = res.add(None, OWNER_NONE);
        let free = res.add(None, OWNER_NONE);
        let mut tasks = mk_tasks(2);
        tasks[0].locks.push(shared); // heavier task, conflicted
        tasks[1].locks.push(free);
        let q = Queue::new(4);
        q.put(100, TaskId(0));
        q.put(1, TaskId(1));
        // Pre-lock the shared resource: task 0 must be skipped.
        assert!(res.try_lock(shared));
        assert_eq!(q.get(&tasks, &res), Some(TaskId(1)));
        assert!(res.get(free).is_locked(), "returned task keeps its locks");
        res.unlock(free);
        // Task 0 still queued and blocked.
        assert_eq!(q.get(&tasks, &res), None);
        assert_eq!(q.len(), 1);
        res.unlock(shared);
        assert_eq!(q.get(&tasks, &res), Some(TaskId(0)));
        res.unlock(shared);
        assert!(res.all_quiescent());
    }

    #[test]
    fn get_rolls_back_partial_locks() {
        let mut res = ResTable::new();
        let a = res.add(None, OWNER_NONE);
        let b = res.add(None, OWNER_NONE);
        let mut tasks = mk_tasks(1);
        tasks[0].locks.extend([a, b]);
        let q = Queue::new(2);
        q.put(1, TaskId(0));
        assert!(res.try_lock(b)); // second lock will fail
        assert_eq!(q.get(&tasks, &res), None);
        assert!(!res.get(a).is_locked(), "partial lock on `a` leaked");
        res.unlock(b);
        assert_eq!(q.get(&tasks, &res), Some(TaskId(0)));
        res.unlock(a);
        res.unlock(b);
        assert!(res.all_quiescent());
    }

    #[test]
    fn total_key_tracks_contents() {
        let tasks = mk_tasks(2);
        let res = ResTable::new();
        let q = Queue::new(2);
        q.put(5, TaskId(0));
        q.put(7, TaskId(1));
        assert_eq!(q.total_key(), 12);
        q.get(&tasks, &res);
        assert_eq!(q.total_key(), 5);
        q.clear();
        assert_eq!(q.total_key(), 0);
    }

    #[test]
    fn stats_count_misses() {
        let tasks = mk_tasks(1);
        let res = ResTable::new();
        let q = Queue::new(1);
        assert_eq!(q.get(&tasks, &res), None);
        let (gets, misses, ..) = q.stats.snapshot();
        assert_eq!((gets, misses), (0, 1));
    }

    #[test]
    fn concurrent_put_get_loses_nothing() {
        use std::sync::atomic::AtomicU64;
        use std::sync::Arc;
        let n = 4000usize;
        let tasks: Arc<Vec<Task>> = Arc::new(mk_tasks(n));
        let res = Arc::new(ResTable::new());
        let q = Arc::new(Queue::new(n));
        let got = Arc::new(AtomicU64::new(0));
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in (p..n).step_by(2) {
                        q.put(i as i64, TaskId(i as u32));
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                let tasks = Arc::clone(&tasks);
                let res = Arc::clone(&res);
                let got = Arc::clone(&got);
                std::thread::spawn(move || {
                    let mut local = 0u64;
                    let mut idle = 0;
                    while idle < 10_000 {
                        match q.get(&tasks, &res) {
                            Some(_) => {
                                local += 1;
                                idle = 0;
                            }
                            None => {
                                idle += 1;
                                // Let the producers run (single-core CI).
                                std::thread::yield_now();
                            }
                        }
                    }
                    got.fetch_add(local, Ordering::Relaxed);
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(got.load(Ordering::Relaxed), n as u64);
        assert!(q.is_empty());
    }
}
