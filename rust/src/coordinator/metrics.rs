//! Execution metrics: per-task timeline records and aggregated accounting.
//!
//! Figures 9 and 12 of the paper are Gantt-style plots of (core, start,
//! end, task type); Figure 13 is accumulated cost per task type plus the
//! scheduler overhead (`qsched_gettask` time). Both are derived from
//! [`TimelineRecord`]s collected per worker (lock-free: each worker owns
//! its buffer) and merged after the run.
//!
//! These metrics cover one `Scheduler::run` / `run_sim` invocation of a
//! single graph. The server's per-*job* accounting is separate and
//! layered above: `server::protocol::JobReport` carries the
//! queue/setup/service/dispatch split of one job through the shared
//! pool, and `server::stats` aggregates those per tenant — including
//! the amortized per-job dispatch overhead that `repro bench-server
//! --batch` compares fused vs unfused.

use super::task::TaskId;

/// One executed task on the timeline. Times are in nanoseconds from the
/// start of `run` — real time for the threaded executor, virtual time for
/// the simulator.
#[derive(Clone, Copy, Debug)]
pub struct TimelineRecord {
    pub tid: TaskId,
    pub type_id: u32,
    pub worker: u32,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Time spent inside `gettask` before this task was acquired
    /// (scheduler overhead attributable to this task).
    pub get_ns: u64,
    /// Whether the task was stolen from a non-preferred queue.
    pub stolen: bool,
}

impl TimelineRecord {
    #[inline]
    pub fn duration_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }
}

/// Metrics for one completed run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Wall-clock (or virtual) duration of the whole run, ns.
    pub elapsed_ns: u64,
    /// Number of workers/cores.
    pub workers: usize,
    /// All timeline records, sorted by start time (empty unless
    /// `record_timeline` was enabled).
    pub timeline: Vec<TimelineRecord>,
    /// Tasks executed.
    pub tasks_run: usize,
    /// Tasks acquired via work stealing.
    pub tasks_stolen: usize,
    /// Total ns spent inside `gettask` across all workers (overhead).
    pub gettask_ns: u64,
    /// Total ns workers sat idle waiting for work (starvation, not
    /// scheduler overhead; only the virtual-time executor separates it —
    /// the threaded executor folds idle spinning into `gettask_ns`).
    pub idle_ns: u64,
    /// Total ns spent executing task functions across all workers.
    pub exec_ns: u64,
}

impl RunMetrics {
    /// Accumulated execution time per task type, ns — the Fig. 13 series.
    pub fn cost_by_type(&self) -> Vec<(u32, u64)> {
        let mut acc: std::collections::BTreeMap<u32, u64> = Default::default();
        for r in &self.timeline {
            *acc.entry(r.type_id).or_insert(0) += r.duration_ns();
        }
        acc.into_iter().collect()
    }

    /// Scheduler overhead fraction: gettask time / (gettask + exec).
    /// The paper's Fig. 13 claim is ~1% at 64 cores.
    pub fn overhead_fraction(&self) -> f64 {
        let denom = (self.gettask_ns + self.exec_ns) as f64;
        if denom == 0.0 {
            0.0
        } else {
            self.gettask_ns as f64 / denom
        }
    }

    /// Parallel efficiency relative to a given single-core time:
    /// `t1 / (n * tn)` — the right-hand panels of Figs 8 and 11.
    pub fn parallel_efficiency(&self, t1_ns: u64) -> f64 {
        if self.elapsed_ns == 0 || self.workers == 0 {
            return 0.0;
        }
        t1_ns as f64 / (self.workers as f64 * self.elapsed_ns as f64)
    }

    /// Utilization: fraction of worker-time spent executing tasks.
    pub fn utilization(&self) -> f64 {
        if self.elapsed_ns == 0 || self.workers == 0 {
            return 0.0;
        }
        self.exec_ns as f64 / (self.elapsed_ns as f64 * self.workers as f64)
    }

    /// Write the timeline as CSV: `worker,start_ns,end_ns,type,tid,stolen`.
    /// The plot scripts under `python/` consume this to draw Figs 9/12.
    pub fn write_timeline_csv<W: std::io::Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(w, "worker,start_ns,end_ns,type,tid,stolen")?;
        for r in &self.timeline {
            writeln!(
                w,
                "{},{},{},{},{},{}",
                r.worker, r.start_ns, r.end_ns, r.type_id, r.tid.0, r.stolen as u8
            )?;
        }
        Ok(())
    }

    /// Verify that no two records on the same worker overlap and that no
    /// two records anywhere overlap while locking a common resource — the
    /// conflict-correctness oracle used by the property tests.
    pub fn check_no_worker_overlap(&self) -> bool {
        let mut by_worker: std::collections::BTreeMap<u32, Vec<(u64, u64)>> = Default::default();
        for r in &self.timeline {
            by_worker.entry(r.worker).or_default().push((r.start_ns, r.end_ns));
        }
        for (_, mut iv) in by_worker {
            iv.sort_unstable();
            for pair in iv.windows(2) {
                if pair[1].0 < pair[0].1 {
                    return false;
                }
            }
        }
        true
    }
}

/// Per-worker collector. Owned exclusively by one worker during the run.
#[derive(Debug, Default)]
pub struct WorkerMetrics {
    pub records: Vec<TimelineRecord>,
    pub tasks_run: usize,
    pub tasks_stolen: usize,
    pub gettask_ns: u64,
    pub idle_ns: u64,
    pub exec_ns: u64,
}

impl WorkerMetrics {
    pub fn with_capacity(n: usize) -> Self {
        Self { records: Vec::with_capacity(n), ..Self::default() }
    }
}

/// Merge per-worker collections into one [`RunMetrics`].
pub fn merge(
    workers: Vec<WorkerMetrics>,
    elapsed_ns: u64,
    record_timeline: bool,
) -> RunMetrics {
    let mut m = RunMetrics {
        elapsed_ns,
        workers: workers.len(),
        ..Default::default()
    };
    for w in workers {
        m.tasks_run += w.tasks_run;
        m.tasks_stolen += w.tasks_stolen;
        m.gettask_ns += w.gettask_ns;
        m.idle_ns += w.idle_ns;
        m.exec_ns += w.exec_ns;
        if record_timeline {
            m.timeline.extend(w.records);
        }
    }
    m.timeline.sort_unstable_by_key(|r| (r.start_ns, r.worker));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(worker: u32, s: u64, e: u64, ty: u32) -> TimelineRecord {
        TimelineRecord {
            tid: TaskId(0),
            type_id: ty,
            worker,
            start_ns: s,
            end_ns: e,
            get_ns: 0,
            stolen: false,
        }
    }

    #[test]
    fn cost_by_type_accumulates() {
        let m = RunMetrics {
            timeline: vec![rec(0, 0, 10, 1), rec(0, 10, 30, 2), rec(1, 0, 5, 1)],
            ..Default::default()
        };
        assert_eq!(m.cost_by_type(), vec![(1, 15), (2, 20)]);
    }

    #[test]
    fn overhead_fraction_bounds() {
        let m = RunMetrics { gettask_ns: 1, exec_ns: 99, ..Default::default() };
        assert!((m.overhead_fraction() - 0.01).abs() < 1e-12);
        let z = RunMetrics::default();
        assert_eq!(z.overhead_fraction(), 0.0);
    }

    #[test]
    fn efficiency_perfect_scaling() {
        let m = RunMetrics { elapsed_ns: 250, workers: 4, ..Default::default() };
        assert!((m.parallel_efficiency(1000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn worker_overlap_detected() {
        let good = RunMetrics {
            timeline: vec![rec(0, 0, 10, 0), rec(0, 10, 20, 0), rec(1, 5, 15, 0)],
            ..Default::default()
        };
        assert!(good.check_no_worker_overlap());
        let bad = RunMetrics {
            timeline: vec![rec(0, 0, 10, 0), rec(0, 9, 20, 0)],
            ..Default::default()
        };
        assert!(!bad.check_no_worker_overlap());
    }

    #[test]
    fn merge_aggregates_and_sorts() {
        let w0 = WorkerMetrics {
            records: vec![rec(0, 10, 20, 0)],
            tasks_run: 1,
            tasks_stolen: 0,
            gettask_ns: 5,
            idle_ns: 1,
            exec_ns: 10,
        };
        let w1 = WorkerMetrics {
            records: vec![rec(1, 0, 10, 0)],
            tasks_run: 1,
            tasks_stolen: 1,
            gettask_ns: 7,
            idle_ns: 2,
            exec_ns: 10,
        };
        let m = merge(vec![w0, w1], 20, true);
        assert_eq!(m.tasks_run, 2);
        assert_eq!(m.tasks_stolen, 1);
        assert_eq!(m.gettask_ns, 12);
        assert_eq!(m.idle_ns, 3);
        assert_eq!(m.timeline[0].worker, 1, "sorted by start time");
        assert!((m.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_shape() {
        let m = RunMetrics { timeline: vec![rec(0, 0, 10, 3)], ..Default::default() };
        let mut buf = Vec::new();
        m.write_timeline_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("worker,start_ns"));
        assert!(s.contains("0,0,10,3,0,0"));
    }
}
