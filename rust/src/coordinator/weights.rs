//! Critical-path task weights (paper §3.1), over the frozen CSR layout.
//!
//! `weight_i = cost_i + max_{j in unlocks_i} weight_j`, computed by
//! traversing the task DAG in *reverse* topological order (Kahn 1962),
//! in O(tasks + dependencies). A side product is cycle detection: if the
//! traversal cannot consume every task, the "graph" was not a DAG.
//!
//! The traversal reads the compiled graph's unlock spans (one shared
//! `u32` arena — see `compiled.rs`) and writes the per-instance weight
//! array; it runs both at freeze time (`CompiledGraph::freeze`) and on
//! cost relearning (`Scheduler::relearn_costs`).

use super::compiled::CompiledGraph;
use super::error::{Result, SchedError};

/// Compute every task's weight in place on the compiled graph.
pub(crate) fn compute_weights(g: &mut CompiledGraph) -> Result<()> {
    let n = g.len();
    let meta = std::sync::Arc::clone(&g.meta);
    // out_degree[i] = number of tasks i unlocks that are still unprocessed.
    let mut out_degree: Vec<u32> = (0..n)
        .map(|i| meta.unlocks[i].len)
        .collect();
    // Seed: sinks (tasks that unlock nothing) have weight = cost.
    let mut stack: Vec<u32> = (0..n as u32).filter(|&i| out_degree[i as usize] == 0).collect();
    // Reverse adjacency: who unlocks me? We need predecessors to
    // decrement out-degrees, so build the linked heads once (O(E)).
    let mut pred_heads: Vec<i64> = vec![-1; n];
    let mut pred_links: Vec<(u32, i64)> = Vec::new(); // (pred, next)
    for i in 0..n {
        for &succ in &meta.adj[meta.unlocks[i].range()] {
            let s = succ as usize;
            pred_links.push((i as u32, pred_heads[s]));
            pred_heads[s] = (pred_links.len() - 1) as i64;
        }
    }
    let mut processed = 0usize;
    while let Some(i) = stack.pop() {
        processed += 1;
        let best_child = meta.adj[meta.unlocks[i as usize].range()]
            .iter()
            .map(|&u| g.weight[u as usize])
            .max()
            .unwrap_or(0);
        g.weight[i as usize] = g.cost[i as usize] + best_child;
        // Decrement each predecessor's remaining out-degree.
        let mut link = pred_heads[i as usize];
        while link >= 0 {
            let (pred, next) = pred_links[link as usize];
            out_degree[pred as usize] -= 1;
            if out_degree[pred as usize] == 0 {
                stack.push(pred);
            }
            link = next;
        }
    }
    if processed != n {
        // Find one task still unprocessed for the error message.
        let example = (0..n)
            .find(|&i| out_degree[i] != 0)
            .map(|i| i as u32)
            .unwrap_or(0);
        return Err(SchedError::Cycle { ntasks: n - processed, example });
    }
    Ok(())
}

/// Length (total cost) of the critical path = max task weight.
pub fn critical_path(g: &CompiledGraph) -> i64 {
    g.weight.iter().copied().max().unwrap_or(0)
}

/// Sum of all task costs = total serial work. `work / critical_path` bounds
/// the achievable speedup (used to sanity-check the Fig 8 / Fig 11 curves).
pub fn total_work(g: &CompiledGraph) -> i64 {
    g.cost.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::ResTable;
    use crate::coordinator::task::{Task, TaskFlags, TaskId};

    fn mk(costs: &[i64], deps: &[(usize, usize)]) -> Result<CompiledGraph> {
        // deps: (a, b) means b depends on a, i.e. a unlocks b.
        let mut ts: Vec<Task> = costs
            .iter()
            .map(|&c| Task::new(0, TaskFlags::default(), vec![], c))
            .collect();
        for &(a, b) in deps {
            ts[a].add_unlock(TaskId(b as u32));
        }
        CompiledGraph::freeze(&ts, &ResTable::new())
    }

    #[test]
    fn single_task() {
        let g = mk(&[7], &[]).unwrap();
        assert_eq!(g.weight(0), 7);
        assert_eq!(critical_path(&g), 7);
    }

    #[test]
    fn chain_accumulates() {
        let g = mk(&[1, 2, 3], &[(0, 1), (1, 2)]).unwrap();
        assert_eq!(g.weight(2), 3);
        assert_eq!(g.weight(1), 5);
        assert_eq!(g.weight(0), 6);
    }

    #[test]
    fn diamond_takes_max_branch() {
        //   0 -> 1 -> 3 ; 0 -> 2 -> 3, costs below
        let g = mk(&[1, 10, 2, 4], &[(0, 1), (0, 2), (1, 3), (2, 3)]).unwrap();
        assert_eq!(g.weight(3), 4);
        assert_eq!(g.weight(1), 14);
        assert_eq!(g.weight(2), 6);
        assert_eq!(g.weight(0), 15, "must follow the heavier branch");
        assert_eq!(total_work(&g), 17);
    }

    #[test]
    fn figure5_style_graph() {
        // Mirrors the paper's Fig. 5: weight = cost of critical path below.
        let g = mk(&[2, 3, 1, 5, 2], &[(0, 2), (1, 2), (2, 3), (2, 4)]).unwrap();
        assert_eq!(g.weight(3), 5);
        assert_eq!(g.weight(4), 2);
        assert_eq!(g.weight(2), 1 + 5);
        assert_eq!(g.weight(0), 2 + 6);
        assert_eq!(g.weight(1), 3 + 6);
    }

    #[test]
    fn cycle_detected() {
        match mk(&[1, 1, 1], &[(0, 1), (1, 2), (2, 0)]) {
            Err(SchedError::Cycle { ntasks, .. }) => assert_eq!(ntasks, 3),
            other => panic!("expected cycle, got {:?}", other.map(|g| g.len())),
        }
    }

    #[test]
    fn self_loop_rejected() {
        // A self-dependency is caught by freeze validation before the
        // weight pass even runs.
        assert!(mk(&[1], &[(0, 0)]).is_err());
    }

    #[test]
    fn disconnected_components() {
        let g = mk(&[4, 1, 2], &[(1, 2)]).unwrap();
        assert_eq!(g.weight(0), 4);
        assert_eq!(g.weight(1), 3);
        assert_eq!(critical_path(&g), 4);
    }

    #[test]
    fn empty_graph() {
        let g = mk(&[], &[]).unwrap();
        assert_eq!(critical_path(&g), 0);
        assert_eq!(total_work(&g), 0);
    }

    #[test]
    fn wide_fanout() {
        // One root unlocking 100 sinks of increasing cost.
        let costs: Vec<i64> = std::iter::once(1).chain(1..=100).collect();
        let deps: Vec<(usize, usize)> = (1..=100).map(|i| (0, i)).collect();
        let g = mk(&costs, &deps).unwrap();
        assert_eq!(g.weight(0), 101);
    }
}
