//! Critical-path task weights (paper §3.1).
//!
//! `weight_i = cost_i + max_{j in unlocks_i} weight_j`, computed by
//! traversing the task DAG in *reverse* topological order (Kahn 1962),
//! in O(tasks + dependencies). A side product is cycle detection: if the
//! traversal cannot consume every task, the "graph" was not a DAG.

use super::error::{Result, SchedError};
use super::task::Task;

/// Compute every task's weight in place. Returns the number of tasks on
/// the longest critical path's root set (diagnostic) or a cycle error.
pub fn compute_weights(tasks: &mut [Task]) -> Result<()> {
    let n = tasks.len();
    // out_degree[i] = number of tasks i unlocks that are still unprocessed.
    let mut out_degree: Vec<u32> = tasks.iter().map(|t| t.unlocks.len() as u32).collect();
    // Seed: sinks (tasks that unlock nothing) have weight = cost.
    let mut stack: Vec<u32> = (0..n as u32).filter(|&i| out_degree[i as usize] == 0).collect();
    // Reverse adjacency: who unlocks me? Built on the fly would be O(E);
    // we need predecessors to decrement out-degrees, so build it once.
    let mut pred_heads: Vec<i64> = vec![-1; n];
    let mut pred_links: Vec<(u32, i64)> = Vec::new(); // (pred, next)
    for (i, t) in tasks.iter().enumerate() {
        for &succ in &t.unlocks {
            let s = succ.idx();
            pred_links.push((i as u32, pred_heads[s]));
            pred_heads[s] = (pred_links.len() - 1) as i64;
        }
    }
    let mut processed = 0usize;
    while let Some(i) = stack.pop() {
        processed += 1;
        let t = &tasks[i as usize];
        let best_child = t
            .unlocks
            .iter()
            .map(|u| tasks[u.idx()].weight)
            .max()
            .unwrap_or(0);
        let w = tasks[i as usize].cost + best_child;
        tasks[i as usize].weight = w;
        // Decrement each predecessor's remaining out-degree.
        let mut link = pred_heads[i as usize];
        while link >= 0 {
            let (pred, next) = pred_links[link as usize];
            out_degree[pred as usize] -= 1;
            if out_degree[pred as usize] == 0 {
                stack.push(pred);
            }
            link = next;
        }
    }
    if processed != n {
        // Find one task still unprocessed for the error message.
        let example = (0..n)
            .find(|&i| out_degree[i] != 0)
            .map(|i| i as u32)
            .unwrap_or(0);
        return Err(SchedError::Cycle { ntasks: n - processed, example });
    }
    Ok(())
}

/// Length (total cost) of the critical path = max task weight.
pub fn critical_path(tasks: &[Task]) -> i64 {
    tasks.iter().map(|t| t.weight).max().unwrap_or(0)
}

/// Sum of all task costs = total serial work. `work / critical_path` bounds
/// the achievable speedup (used to sanity-check the Fig 8 / Fig 11 curves).
pub fn total_work(tasks: &[Task]) -> i64 {
    tasks.iter().map(|t| t.cost).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{TaskFlags, TaskId};

    fn mk(costs: &[i64], deps: &[(usize, usize)]) -> Vec<Task> {
        // deps: (a, b) means b depends on a, i.e. a unlocks b.
        let mut ts: Vec<Task> = costs
            .iter()
            .map(|&c| Task::new(0, TaskFlags::default(), vec![], c))
            .collect();
        for &(a, b) in deps {
            ts[a].unlocks.push(TaskId(b as u32));
        }
        ts
    }

    #[test]
    fn single_task() {
        let mut ts = mk(&[7], &[]);
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[0].weight, 7);
        assert_eq!(critical_path(&ts), 7);
    }

    #[test]
    fn chain_accumulates() {
        let mut ts = mk(&[1, 2, 3], &[(0, 1), (1, 2)]);
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[2].weight, 3);
        assert_eq!(ts[1].weight, 5);
        assert_eq!(ts[0].weight, 6);
    }

    #[test]
    fn diamond_takes_max_branch() {
        //   0 -> 1 -> 3 ; 0 -> 2 -> 3, costs below
        let mut ts = mk(&[1, 10, 2, 4], &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[3].weight, 4);
        assert_eq!(ts[1].weight, 14);
        assert_eq!(ts[2].weight, 6);
        assert_eq!(ts[0].weight, 15, "must follow the heavier branch");
        assert_eq!(total_work(&ts), 17);
    }

    #[test]
    fn figure5_style_graph() {
        // Mirrors the paper's Fig. 5: weight = cost of critical path below.
        let mut ts = mk(
            &[2, 3, 1, 5, 2],
            &[(0, 2), (1, 2), (2, 3), (2, 4)],
        );
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[3].weight, 5);
        assert_eq!(ts[4].weight, 2);
        assert_eq!(ts[2].weight, 1 + 5);
        assert_eq!(ts[0].weight, 2 + 6);
        assert_eq!(ts[1].weight, 3 + 6);
    }

    #[test]
    fn cycle_detected() {
        let mut ts = mk(&[1, 1, 1], &[(0, 1), (1, 2), (2, 0)]);
        match compute_weights(&mut ts) {
            Err(SchedError::Cycle { ntasks, .. }) => assert_eq!(ntasks, 3),
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn self_loop_is_cycle() {
        let mut ts = mk(&[1], &[(0, 0)]);
        assert!(compute_weights(&mut ts).is_err());
    }

    #[test]
    fn disconnected_components() {
        let mut ts = mk(&[4, 1, 2], &[(1, 2)]);
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[0].weight, 4);
        assert_eq!(ts[1].weight, 3);
        assert_eq!(critical_path(&ts), 4);
    }

    #[test]
    fn empty_graph() {
        let mut ts = mk(&[], &[]);
        compute_weights(&mut ts).unwrap();
        assert_eq!(critical_path(&ts), 0);
        assert_eq!(total_work(&ts), 0);
    }

    #[test]
    fn wide_fanout() {
        // One root unlocking 100 sinks of increasing cost.
        let costs: Vec<i64> = std::iter::once(1).chain(1..=100).collect();
        let deps: Vec<(usize, usize)> = (1..=100).map(|i| (0, i)).collect();
        let mut ts = mk(&costs, &deps);
        compute_weights(&mut ts).unwrap();
        assert_eq!(ts[0].weight, 101);
    }
}
