//! Task-graph statistics and structural validation.
//!
//! The paper reports, for each experiment, the number of tasks,
//! dependencies, resources, locks, and uses (§4.1: "a total of 11 440
//! tasks with 21 824 dependencies, as well as 1 024 resources with 21 856
//! locks and 11 408 uses"). [`GraphStats`] regenerates those text tables,
//! and [`validate`] performs the structural checks `prepare()` relies on.

use std::collections::HashSet;

use super::error::{Result, SchedError};
use super::resource::ResTable;
use super::task::Task;

/// Counts matching the paper's per-experiment graph summaries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GraphStats {
    pub tasks: usize,
    pub dependencies: usize,
    pub resources: usize,
    pub locks: usize,
    pub uses: usize,
    /// Tasks with no dependencies (initially runnable).
    pub roots: usize,
    /// Tasks unlocking nothing (sinks).
    pub sinks: usize,
    /// Bytes of task payload data.
    pub payload_bytes: usize,
}

impl GraphStats {
    pub fn of(tasks: &[Task], res: &ResTable) -> Self {
        let mut s = Self {
            tasks: tasks.len(),
            resources: res.len(),
            ..Self::default()
        };
        let mut wait = vec![0u32; tasks.len()];
        for t in tasks {
            s.dependencies += t.unlocks.len();
            s.locks += t.locks.len();
            s.uses += t.uses.len();
            s.payload_bytes += t.data.len();
            for u in &t.unlocks {
                wait[u.idx()] += 1;
            }
        }
        s.roots = wait.iter().filter(|&&w| w == 0).count();
        s.sinks = tasks.iter().filter(|t| t.unlocks.is_empty()).count();
        s
    }

    /// Approximate memory footprint of the task graph in bytes, for the
    /// §4.2 "storing the tasks, resources, and dependencies required XXX
    /// MB" style reporting.
    pub fn memory_bytes(&self) -> usize {
        self.tasks * std::mem::size_of::<Task>()
            + (self.dependencies + self.locks + self.uses) * 8
            + self.payload_bytes
            + self.resources * 24
    }
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} tasks with {} dependencies, {} resources with {} locks and {} uses \
             ({} roots, {} sinks, {:.2} MB graph)",
            self.tasks,
            self.dependencies,
            self.resources,
            self.locks,
            self.uses,
            self.roots,
            self.sinks,
            self.memory_bytes() as f64 / 1e6
        )
    }
}

/// Structural validation performed by `Scheduler::prepare`:
/// * every unlock/lock/use handle is in range,
/// * no task unlocks itself,
/// * duplicate unlock edges are reported (they would double-decrement the
///   wait counter: legal in the paper's C code but almost always a bug).
pub fn validate(tasks: &[Task], res: &ResTable) -> Result<()> {
    let nt = tasks.len();
    let nr = res.len();
    for (i, t) in tasks.iter().enumerate() {
        let mut seen: HashSet<u32> = HashSet::with_capacity(t.unlocks.len());
        for u in &t.unlocks {
            if u.idx() >= nt {
                return Err(SchedError::BadTask(u.0, nt));
            }
            if u.idx() == i {
                return Err(SchedError::SelfDependency(i as u32));
            }
            seen.insert(u.0);
        }
        for r in t.locks.iter().chain(t.uses.iter()) {
            if r.idx() >= nr {
                return Err(SchedError::BadRes(r.0, nr));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::Payload;
    use crate::coordinator::resource::OWNER_NONE;
    use crate::coordinator::task::{TaskFlags, TaskId};

    #[test]
    fn stats_counts() {
        let mut res = ResTable::new();
        let r0 = res.add(None, OWNER_NONE);
        let r1 = res.add(Some(r0), OWNER_NONE);
        let mut tasks = vec![
            Task::new(0, TaskFlags::default(), (1i32, 2i32).encode(), 1),
            Task::new(1, TaskFlags::default(), vec![], 2),
            Task::new(2, TaskFlags::default(), vec![], 3),
        ];
        tasks[0].unlocks.push(TaskId(1));
        tasks[0].unlocks.push(TaskId(2));
        tasks[1].unlocks.push(TaskId(2));
        tasks[0].locks.push(r0);
        tasks[1].locks.push(r1);
        tasks[1].uses.push(r0);
        let s = GraphStats::of(&tasks, &res);
        assert_eq!(s.tasks, 3);
        assert_eq!(s.dependencies, 3);
        assert_eq!(s.resources, 2);
        assert_eq!(s.locks, 2);
        assert_eq!(s.uses, 1);
        assert_eq!(s.roots, 1);
        assert_eq!(s.sinks, 1);
        assert_eq!(s.payload_bytes, 8);
        assert!(s.memory_bytes() > 0);
        assert!(s.to_string().contains("3 tasks"));
    }

    #[test]
    fn validate_rejects_out_of_range_unlock() {
        let res = ResTable::new();
        let mut tasks = vec![Task::new(0, TaskFlags::default(), vec![], 1)];
        tasks[0].unlocks.push(TaskId(5));
        assert!(matches!(validate(&tasks, &res), Err(SchedError::BadTask(5, 1))));
    }

    #[test]
    fn validate_rejects_self_dep() {
        let res = ResTable::new();
        let mut tasks = vec![Task::new(0, TaskFlags::default(), vec![], 1)];
        tasks[0].unlocks.push(TaskId(0));
        assert!(matches!(validate(&tasks, &res), Err(SchedError::SelfDependency(0))));
    }

    #[test]
    fn validate_rejects_bad_resource() {
        let res = ResTable::new();
        let mut tasks = vec![Task::new(0, TaskFlags::default(), vec![], 1)];
        tasks[0].locks.push(crate::coordinator::resource::ResId(0));
        assert!(matches!(validate(&tasks, &res), Err(SchedError::BadRes(0, 0))));
    }

    #[test]
    fn validate_ok_on_empty() {
        assert!(validate(&[], &ResTable::new()).is_ok());
    }
}
