//! Task-graph statistics.
//!
//! The paper reports, for each experiment, the number of tasks,
//! dependencies, resources, locks, and uses (§4.1: "a total of 11 440
//! tasks with 21 824 dependencies, as well as 1 024 resources with 21 856
//! locks and 11 408 uses"). [`GraphStats`] regenerates those text tables.
//!
//! Two constructors exist for the two graph representations:
//! [`GraphStats::of_compiled`] reads the frozen CSR layout (the normal,
//! post-`prepare()` path), and `GraphStats::of` (defined in
//! `builder.rs`, beside the other build-side `Vec` walkers) covers a
//! graph still under construction. Structural *validation* is performed
//! by the freeze itself (`CompiledGraph::freeze`): handle ranges,
//! self-dependencies, and — via weight computation — cycles.

use super::compiled::CompiledGraph;
use super::resource::ResTable;

/// Counts matching the paper's per-experiment graph summaries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GraphStats {
    pub tasks: usize,
    pub dependencies: usize,
    pub resources: usize,
    pub locks: usize,
    pub uses: usize,
    /// Tasks with no dependencies (initially runnable).
    pub roots: usize,
    /// Tasks unlocking nothing (sinks).
    pub sinks: usize,
    /// Bytes of task payload data.
    pub payload_bytes: usize,
}

impl GraphStats {
    /// Stats of a frozen graph, read off the CSR spans.
    pub fn of_compiled(g: &CompiledGraph, res: &ResTable) -> Self {
        let n = g.len();
        let mut s = Self {
            tasks: n,
            resources: res.len(),
            payload_bytes: g.meta().payload.len(),
            roots: g.roots().len(),
            ..Self::default()
        };
        for i in 0..n {
            s.dependencies += g.unlock_ids(i).len();
            s.locks += g.lock_ids(i).len();
            s.uses += g.use_ids(i).len();
            if g.unlock_ids(i).is_empty() {
                s.sinks += 1;
            }
        }
        s
    }

    /// Approximate memory footprint of the frozen task graph in bytes,
    /// for the §4.2 "storing the tasks, resources, and dependencies
    /// required XXX MB" style reporting. Reflects the flattened layout:
    /// SoA scalars + spans per task, one padded run-state line per
    /// task, the shared `u32` adjacency arena, the payload arena, and
    /// one padded cache line per resource.
    pub fn memory_bytes(&self) -> usize {
        // type_id + flags + wait0 (SoA) + cost + weight + 4 spans.
        let per_task_soa = 4 + 1 + 4 + 8 + 8 + 4 * 8;
        self.tasks * (per_task_soa + 64 /* padded TaskRunState */)
            + (self.dependencies + self.locks + self.uses) * 4
            + self.payload_bytes
            + self.resources * 64 /* padded Resource */
    }
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} tasks with {} dependencies, {} resources with {} locks and {} uses \
             ({} roots, {} sinks, {:.2} MB graph)",
            self.tasks,
            self.dependencies,
            self.resources,
            self.locks,
            self.uses,
            self.roots,
            self.sinks,
            self.memory_bytes() as f64 / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::Payload;
    use crate::coordinator::resource::OWNER_NONE;
    use crate::coordinator::task::{Task, TaskFlags, TaskId};

    #[test]
    fn stats_counts_compiled() {
        let mut res = ResTable::new();
        let r0 = res.add(None, OWNER_NONE);
        let r1 = res.add(Some(r0), OWNER_NONE);
        let mut tasks = vec![
            Task::new(0, TaskFlags::default(), (1i32, 2i32).encode(), 1),
            Task::new(1, TaskFlags::default(), vec![], 2),
            Task::new(2, TaskFlags::default(), vec![], 3),
        ];
        tasks[0].add_unlock(TaskId(1));
        tasks[0].add_unlock(TaskId(2));
        tasks[1].add_unlock(TaskId(2));
        tasks[0].add_lock(r0);
        tasks[1].add_lock(r1);
        tasks[1].add_use(r0);
        let g = CompiledGraph::freeze(&tasks, &res).unwrap();
        let s = GraphStats::of_compiled(&g, &res);
        assert_eq!(s.tasks, 3);
        assert_eq!(s.dependencies, 3);
        assert_eq!(s.resources, 2);
        assert_eq!(s.locks, 2);
        assert_eq!(s.uses, 1);
        assert_eq!(s.roots, 1);
        assert_eq!(s.sinks, 1);
        assert_eq!(s.payload_bytes, 8);
        assert!(s.memory_bytes() > 0);
        assert!(s.to_string().contains("3 tasks"));
        // The build-side constructor agrees on this dedup-free graph.
        assert_eq!(GraphStats::of(&tasks, &res), s);
    }
}
