//! Hierarchical resources and the lock/hold protocol (paper §3.2).
//!
//! A resource is *locked* when a task holds it exclusively, and *held* when
//! any hierarchical descendant is locked. Locking a resource requires the
//! resource itself to be unlocked and un-held, and transitively marks every
//! ancestor as held — so a lock on a child cell excludes a lock on any
//! ancestor and vice versa, which is exactly the conflict semantics the
//! Barnes-Hut example relies on.
//!
//! The implementation follows the paper's CAS pseudo-code, including the
//! subtle double-check of `hold` after acquiring the short `lock` in
//! `try_lock`, and the rollback of partially acquired ancestor holds.

use std::sync::atomic::{AtomicI32, AtomicU32, Ordering};

/// Handle to a resource within one scheduler (dense index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResId(pub u32);

impl ResId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for ResId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Sentinel for "no owner queue" (`qsched_owner_none`).
pub const OWNER_NONE: i32 = -1;

/// A single exclusively-lockable hierarchical resource
/// (paper §3.2 `struct resource`).
///
/// Cache-line-aligned: the `lock`/`hold` words are CAS-ed and
/// re-checked from every worker on every conflict probe, and before
/// padding two unrelated resources shared a 64-byte line — a lock
/// storm on one evicted its neighbors from every other core's cache
/// (§Perf opt E; the resource table is a flat arena, so neighbors are
/// adjacent by construction).
#[derive(Debug)]
#[repr(align(64))]
pub struct Resource {
    /// Hierarchical parent, or `None` for a root resource.
    pub parent: Option<ResId>,
    /// 0 = free, 1 = locked. CAS-only access.
    lock: AtomicU32,
    /// Number of locked descendants ("held" counter).
    hold: AtomicU32,
    /// Queue that last used this resource (cache-affinity hint, §3.4).
    owner: AtomicI32,
}

impl Resource {
    pub fn new(parent: Option<ResId>, owner: i32) -> Self {
        Self {
            parent,
            lock: AtomicU32::new(0),
            hold: AtomicU32::new(0),
            owner: AtomicI32::new(owner),
        }
    }

    #[inline]
    pub fn owner(&self) -> i32 {
        self.owner.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn set_owner(&self, qid: i32) {
        self.owner.store(qid, Ordering::Relaxed);
    }

    /// Is this resource currently locked? (diagnostic only — racy by nature)
    #[inline]
    pub fn is_locked(&self) -> bool {
        self.lock.load(Ordering::Acquire) == 1
    }

    /// Current hold count (diagnostic only).
    #[inline]
    pub fn hold_count(&self) -> u32 {
        self.hold.load(Ordering::Acquire)
    }

    #[inline]
    fn try_acquire_flag(&self) -> bool {
        self.lock
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    #[inline]
    fn release_flag(&self) {
        self.lock.store(0, Ordering::Release);
    }
}

/// The resource table: flat arena of resources plus the hierarchical
/// lock/hold operations, which need access to parents by id.
#[derive(Debug, Default)]
pub struct ResTable {
    res: Vec<Resource>,
}

impl ResTable {
    pub fn new() -> Self {
        Self { res: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.res.len()
    }

    pub fn is_empty(&self) -> bool {
        self.res.is_empty()
    }

    pub fn add(&mut self, parent: Option<ResId>, owner: i32) -> ResId {
        if let Some(p) = parent {
            assert!(p.idx() < self.res.len(), "parent resource out of range");
        }
        let id = ResId(self.res.len() as u32);
        self.res.push(Resource::new(parent, owner));
        id
    }

    #[inline]
    pub fn get(&self, id: ResId) -> &Resource {
        &self.res[id.idx()]
    }

    /// `resource_hold` (§3.2): transiently grab the short lock, bump the
    /// hold counter, release. Fails if the resource is currently locked.
    pub fn try_hold(&self, id: ResId) -> bool {
        let r = self.get(id);
        if !r.try_acquire_flag() {
            return false;
        }
        r.hold.fetch_add(1, Ordering::AcqRel);
        r.release_flag();
        true
    }

    /// Undo one `try_hold`.
    pub fn unhold(&self, id: ResId) {
        let r = self.get(id);
        let prev = r.hold.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "unhold on hold==0");
    }

    /// `resource_lock` (§3.2): exclusively lock `id` and mark every ancestor
    /// held. Returns `false` (with full rollback) if the resource is locked,
    /// held, or any ancestor cannot be held.
    pub fn try_lock(&self, id: ResId) -> bool {
        let r = self.get(id);
        // Quick rejection + short lock acquisition.
        if r.hold.load(Ordering::Acquire) != 0 || !r.try_acquire_flag() {
            return false;
        }
        // Re-check hold under the lock: a concurrent try_hold may have
        // slipped in between the check and the CAS (paper lines 5-8).
        if r.hold.load(Ordering::Acquire) != 0 {
            r.release_flag();
            return false;
        }
        // Walk up the hierarchy holding each ancestor (paper lines 9-10).
        let mut failed_at: Option<ResId> = None;
        let mut up = r.parent;
        while let Some(pid) = up {
            if !self.try_hold(pid) {
                failed_at = Some(pid);
                break;
            }
            up = self.get(pid).parent;
        }
        if let Some(stop) = failed_at {
            // Roll back the holds acquired so far (paper lines 11-15).
            let mut up = r.parent;
            while let Some(pid) = up {
                if pid == stop {
                    break;
                }
                self.unhold(pid);
                up = self.get(pid).parent;
            }
            r.release_flag();
            false
        } else {
            true
        }
    }

    /// Unlock a previously locked resource: release the flag and decrement
    /// every ancestor's hold counter.
    pub fn unlock(&self, id: ResId) {
        let r = self.get(id);
        debug_assert!(r.is_locked(), "unlock on unlocked resource");
        let mut up = r.parent;
        while let Some(pid) = up {
            self.unhold(pid);
            up = self.get(pid).parent;
        }
        r.release_flag();
    }

    /// Depth of a resource in the hierarchy (root = 0). Test/diag helper.
    pub fn depth(&self, id: ResId) -> usize {
        let mut d = 0;
        let mut up = self.get(id).parent;
        while let Some(pid) = up {
            d += 1;
            up = self.get(pid).parent;
        }
        d
    }

    /// Check the global quiescent invariant: no locks, all holds zero.
    /// Used by tests after a run completes.
    pub fn all_quiescent(&self) -> bool {
        self.res
            .iter()
            .all(|r| !r.is_locked() && r.hold_count() == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> (ResTable, Vec<ResId>) {
        // r0 <- r1 <- ... <- r(n-1), each child of the previous.
        let mut t = ResTable::new();
        let mut ids = Vec::new();
        let mut parent = None;
        for _ in 0..n {
            let id = t.add(parent, OWNER_NONE);
            ids.push(id);
            parent = Some(id);
        }
        (t, ids)
    }

    #[test]
    fn resource_occupies_one_cache_line() {
        assert_eq!(std::mem::size_of::<Resource>(), 64);
        assert_eq!(std::mem::align_of::<Resource>(), 64);
    }

    #[test]
    fn lock_unlock_single() {
        let mut t = ResTable::new();
        let r = t.add(None, OWNER_NONE);
        assert!(t.try_lock(r));
        assert!(t.get(r).is_locked());
        assert!(!t.try_lock(r), "double lock must fail");
        t.unlock(r);
        assert!(t.all_quiescent());
        assert!(t.try_lock(r), "relock after unlock");
        t.unlock(r);
    }

    #[test]
    fn child_lock_holds_ancestors() {
        let (t, ids) = chain(3);
        let leaf = ids[2];
        assert!(t.try_lock(leaf));
        assert_eq!(t.get(ids[0]).hold_count(), 1);
        assert_eq!(t.get(ids[1]).hold_count(), 1);
        // Ancestors cannot be locked while held.
        assert!(!t.try_lock(ids[0]));
        assert!(!t.try_lock(ids[1]));
        t.unlock(leaf);
        assert!(t.all_quiescent());
        assert!(t.try_lock(ids[0]));
        t.unlock(ids[0]);
    }

    #[test]
    fn locked_ancestor_blocks_descendant() {
        let (t, ids) = chain(3);
        assert!(t.try_lock(ids[0]));
        // Locking the leaf requires holding ids[0], which is locked.
        assert!(!t.try_lock(ids[2]));
        t.unlock(ids[0]);
        assert!(t.try_lock(ids[2]));
        t.unlock(ids[2]);
        assert!(t.all_quiescent());
    }

    #[test]
    fn siblings_do_not_conflict() {
        let mut t = ResTable::new();
        let root = t.add(None, OWNER_NONE);
        let a = t.add(Some(root), OWNER_NONE);
        let b = t.add(Some(root), OWNER_NONE);
        assert!(t.try_lock(a));
        assert!(t.try_lock(b), "sibling locks are independent");
        assert_eq!(t.get(root).hold_count(), 2);
        t.unlock(a);
        assert_eq!(t.get(root).hold_count(), 1);
        t.unlock(b);
        assert!(t.all_quiescent());
    }

    #[test]
    fn rollback_on_mid_hierarchy_conflict() {
        // root <- mid <- leaf ; lock `mid`, then try to lock `leaf`:
        // holding `mid` fails, and the partial hold on nothing must be
        // rolled back leaving counts unchanged.
        let (t, ids) = chain(3);
        assert!(t.try_lock(ids[1]));
        let root_holds = t.get(ids[0]).hold_count();
        assert!(!t.try_lock(ids[2]));
        assert_eq!(t.get(ids[0]).hold_count(), root_holds, "rollback leaked a hold");
        t.unlock(ids[1]);
        assert!(t.all_quiescent());
    }

    #[test]
    fn hold_blocks_lock_and_vice_versa() {
        let mut t = ResTable::new();
        let r = t.add(None, OWNER_NONE);
        assert!(t.try_hold(r));
        assert!(!t.try_lock(r), "held resource cannot be locked");
        t.unhold(r);
        assert!(t.try_lock(r));
        assert!(!t.try_hold(r), "locked resource cannot be held");
        t.unlock(r);
        assert!(t.all_quiescent());
    }

    #[test]
    fn owner_roundtrip() {
        let mut t = ResTable::new();
        let r = t.add(None, 3);
        assert_eq!(t.get(r).owner(), 3);
        t.get(r).set_owner(7);
        assert_eq!(t.get(r).owner(), 7);
    }

    #[test]
    fn depth_computed() {
        let (t, ids) = chain(4);
        assert_eq!(t.depth(ids[0]), 0);
        assert_eq!(t.depth(ids[3]), 3);
    }

    #[test]
    fn concurrent_lock_stress() {
        use std::sync::Arc;
        // A binary tree of depth 3; threads lock random leaves and verify
        // mutual exclusion via a per-resource "inside" flag.
        let mut t = ResTable::new();
        let root = t.add(None, OWNER_NONE);
        let mut leaves = Vec::new();
        for _ in 0..2 {
            let mid = t.add(Some(root), OWNER_NONE);
            for _ in 0..2 {
                leaves.push(t.add(Some(mid), OWNER_NONE));
            }
        }
        let n_res = t.len();
        let table = Arc::new(t);
        let inside: Arc<Vec<std::sync::atomic::AtomicU32>> =
            Arc::new((0..n_res).map(|_| AtomicU32::new(0)).collect());
        let mut handles = Vec::new();
        for seed in 0..4u64 {
            let table = Arc::clone(&table);
            let inside = Arc::clone(&inside);
            let leaves = leaves.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(seed);
                for _ in 0..2000 {
                    let target = if rng.chance(0.2) {
                        root
                    } else {
                        leaves[rng.index(leaves.len())]
                    };
                    if table.try_lock(target) {
                        let prev = inside[target.idx()].fetch_add(1, Ordering::AcqRel);
                        assert_eq!(prev, 0, "two lockers inside {target}");
                        std::hint::spin_loop();
                        inside[target.idx()].fetch_sub(1, Ordering::AcqRel);
                        table.unlock(target);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(table.all_quiescent());
    }
}
