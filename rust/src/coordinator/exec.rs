//! Threaded executor (`qsched_run`, paper §3.4 and Appendix A).
//!
//! Spawns `nr_threads` workers (scoped std threads — the pthread path of
//! the paper; there is no OpenMP in rust, and the paper's OpenMP mode is
//! itself implemented on top of pthreads). Each worker loops
//! `gettask → fun(task) → done` until the scheduler runs out of tasks.
//! `ExecMode::Spin` busy-waits when no task is available;
//! `ExecMode::Yield` blocks on a condvar like `qsched_flag_yield`.
//!
//! Two executors share the task-execution core defined here:
//!
//! * this module's per-run workers, spawned for one graph and joined
//!   when it drains (`Scheduler::run`), acquiring through the
//!   scheduler's own queues; and
//! * the server's persistent pool (`server::pool`), whose long-lived
//!   workers acquire through the shared cross-job shard layer
//!   (`server::shard`) via `Scheduler::try_acquire`.
//!
//! Both funnel into `exec_task_guarded` below, so panic isolation and
//! measured-cost recording behave identically whichever way a task was
//! acquired.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use super::config::ExecMode;
use super::error::{Result, SchedError};
use super::metrics::{merge, RunMetrics, TimelineRecord, WorkerMetrics};
use super::scheduler::Scheduler;
use super::task::TaskView;
use crate::util::rng::Rng;

impl Scheduler {
    /// `qsched_run`: execute all tasks on `nr_threads` workers. `fun` is
    /// the user execution function receiving `(type, data)` as a
    /// [`TaskView`]; it must be `Sync` since all workers share it.
    ///
    /// Each worker prefers queue `worker_id % nr_queues` (paper §3.4) and
    /// steals from the others when starved.
    pub fn run<F>(&mut self, nr_threads: usize, fun: F) -> Result<RunMetrics>
    where
        F: Fn(TaskView<'_>) + Sync,
    {
        assert!(nr_threads > 0, "need at least one worker");
        self.start()?;
        let t0 = Instant::now();
        let panicked = AtomicBool::new(false);
        let record = self.config.record_timeline;
        let seed = self.config.seed;
        let this: &Scheduler = self;
        let fun = &fun;
        let panicked_ref = &panicked;

        let workers: Vec<WorkerMetrics> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..nr_threads)
                .map(|wid| {
                    scope.spawn(move || {
                        worker_loop(this, wid, nr_threads, seed, record, t0, fun, panicked_ref)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_default())
                .collect()
        });

        if panicked.load(Ordering::Acquire) {
            return Err(SchedError::WorkerPanic);
        }
        let elapsed = t0.elapsed().as_nanos() as u64;
        debug_assert!(self.res.all_quiescent(), "resources leaked locks");
        Ok(merge(workers, elapsed, record))
    }
}

/// Execute one acquired task: run `fun` on the task's view under a panic
/// guard and store the measured time for cost relearning. Does **not**
/// complete the task — callers do their own accounting between execution
/// and [`Scheduler::complete`] (the completion may immediately finalize
/// the whole job on the server, so everything attributed to the task
/// must be recorded first). Returns the measured execution time and
/// whether `fun` panicked.
///
/// This is the execution path shared by the per-run workers below and
/// the server's persistent pool ([`crate::server::pool`]), which draws
/// tasks from many concurrently-active jobs through the shared shard
/// layer ([`crate::server::shard`]) instead of being spawned for one
/// `run()`.
pub(crate) fn exec_task_guarded<F>(s: &Scheduler, tid: super::task::TaskId, fun: &F) -> (u64, bool)
where
    F: Fn(TaskView<'_>) + ?Sized,
{
    let t0 = Instant::now();
    let view = s.task_view(tid);
    // Catch panics so a buggy task fn cannot deadlock the other workers
    // waiting on `waiting > 0`.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| fun(view)));
    let exec_ns = t0.elapsed().as_nanos() as u64;
    s.record_measured(tid, exec_ns);
    (exec_ns, r.is_err())
}

/// [`exec_task_guarded`] followed by [`Scheduler::complete`] — the
/// single-run worker path, which keeps its accounting in thread-local
/// [`WorkerMetrics`] and so has no pre-completion ordering concerns.
pub(crate) fn exec_and_complete<F>(s: &Scheduler, tid: super::task::TaskId, fun: &F) -> (u64, bool)
where
    F: Fn(TaskView<'_>) + ?Sized,
{
    let out = exec_task_guarded(s, tid, fun);
    s.complete(tid);
    out
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<F>(
    s: &Scheduler,
    wid: usize,
    nr_threads: usize,
    seed: u64,
    record: bool,
    t0: Instant,
    fun: &F,
    panicked: &AtomicBool,
) -> WorkerMetrics
where
    F: Fn(TaskView<'_>) + Sync,
{
    let qid = wid % s.nr_queues();
    let mut rng = Rng::new(Rng::split(seed, wid as u64));
    let mut m = WorkerMetrics::with_capacity(if record { 1024 } else { 0 });
    let mut get_started = Instant::now();
    while s.waiting() > 0 {
        if panicked.load(Ordering::Relaxed) {
            break;
        }
        // §Perf opt D: skip the full gettask probe (own queue + steal
        // sweep over nr_queues spin-locks) while nothing is queued.
        let attempt = if s.queued_hint() > 0 {
            s.gettask(qid, &mut rng)
        } else {
            None
        };
        match attempt {
            Some((tid, stolen)) => {
                let acquired = Instant::now();
                let get_ns = acquired.duration_since(get_started).as_nanos() as u64;
                m.gettask_ns += get_ns;
                let type_id = s.task_view(tid).type_id;
                let (exec_ns, did_panic) = exec_and_complete(s, tid, fun);
                let finished = acquired + Duration::from_nanos(exec_ns);
                m.exec_ns += exec_ns;
                m.tasks_run += 1;
                m.tasks_stolen += stolen as usize;
                if record {
                    m.records.push(TimelineRecord {
                        tid,
                        type_id,
                        worker: wid as u32,
                        start_ns: acquired.duration_since(t0).as_nanos() as u64,
                        end_ns: finished.duration_since(t0).as_nanos() as u64,
                        get_ns,
                        stolen,
                    });
                }
                if did_panic {
                    panicked.store(true, Ordering::Release);
                }
                // §Perf: reuse the post-exec timestamp instead of a third
                // clock read per task (complete() above is cheap and its
                // cost is legitimately gettask-side bookkeeping).
                get_started = finished;
            }
            None => {
                match s.config().flags.mode {
                    ExecMode::Spin => {
                        // Back off a little: with more workers than cores
                        // (our 1-core testbed!) pure spinning starves the
                        // task holder.
                        if nr_threads > 1 {
                            std::thread::yield_now();
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                    ExecMode::Yield => {
                        let g = s.wait_lock.lock().unwrap();
                        // Re-check under the lock, then sleep briefly;
                        // `complete`/`enqueue` notify on state changes.
                        if s.waiting() > 0 {
                            let _ = s
                                .wait_cv
                                .wait_timeout(g, Duration::from_millis(1))
                                .unwrap();
                        }
                    }
                }
            }
        }
    }
    // Attribute the final idle stretch to gettask overhead.
    m.gettask_ns += Instant::now().duration_since(get_started).as_nanos() as u64;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{SchedConfig, SchedFlags};
    use crate::coordinator::builder::GraphBuilder;
    use crate::coordinator::payload::Payload;
    use std::sync::atomic::AtomicU64;

    fn diamond(nq: usize) -> (Scheduler, Vec<crate::coordinator::TaskId>) {
        let mut s = Scheduler::new(SchedConfig::new(nq).with_timeline(true)).unwrap();
        let a = s.task(0).payload(&0i32).cost(4).spawn();
        let b = s.task(1).payload(&1i32).cost(2).spawn();
        let c = s.task(2).payload(&2i32).cost(2).spawn();
        let d = s.task(3).payload(&3i32).cost(1).spawn();
        s.add_unlock(a, b);
        s.add_unlock(a, c);
        s.add_unlock(b, d);
        s.add_unlock(c, d);
        s.prepare().unwrap();
        (s, vec![a, b, c, d])
    }

    #[test]
    fn runs_all_tasks_once_single_thread() {
        let (mut s, _) = diamond(1);
        let count = AtomicU64::new(0);
        let m = s
            .run(1, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 4);
        assert_eq!(m.tasks_run, 4);
        assert_eq!(s.waiting(), 0);
        assert!(m.check_no_worker_overlap());
    }

    #[test]
    fn runs_all_tasks_multi_thread() {
        let (mut s, _) = diamond(4);
        let count = AtomicU64::new(0);
        let m = s
            .run(4, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 4);
        assert_eq!(m.workers, 4);
        assert!(m.check_no_worker_overlap());
    }

    #[test]
    fn dependency_order_respected() {
        // Record a completion stamp per task; parents must finish first.
        let (mut s, ids) = diamond(2);
        let order: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        let counter = AtomicU64::new(1);
        s.run(2, |t| {
            let stamp = counter.fetch_add(1, Ordering::SeqCst);
            let idx = i32::decode(t.data) as usize;
            order[idx].store(stamp, Ordering::SeqCst);
        })
        .unwrap();
        let st: Vec<u64> = order.iter().map(|o| o.load(Ordering::SeqCst)).collect();
        let (a, b, c, d) =
            (ids[0].idx(), ids[1].idx(), ids[2].idx(), ids[3].idx());
        assert!(st[a] < st[b] && st[a] < st[c]);
        assert!(st[b] < st[d] && st[c] < st[d]);
    }

    #[test]
    fn conflicts_never_overlap() {
        // 8 tasks all locking one resource; a shared "inside" counter
        // must never exceed 1.
        let mut s = Scheduler::new(SchedConfig::new(4)).unwrap();
        let r = s.add_resource(None, -1);
        for _ in 0..8 {
            let t = s.task(0).spawn();
            s.add_lock(t, r);
        }
        s.prepare().unwrap();
        let inside = AtomicU64::new(0);
        let max_seen = AtomicU64::new(0);
        s.run(4, |_| {
            let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
            max_seen.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_micros(100));
            inside.fetch_sub(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(max_seen.load(Ordering::SeqCst), 1, "conflict violated");
    }

    #[test]
    fn yield_mode_completes() {
        let mut cfg = SchedConfig::new(2);
        cfg.flags = SchedFlags { mode: ExecMode::Yield, ..Default::default() };
        let mut s = Scheduler::new(cfg).unwrap();
        let mut prev = None;
        for _ in 0..16 {
            let t = s.task(0).spawn();
            if let Some(p) = prev {
                s.add_unlock(p, t);
            }
            prev = Some(t);
        }
        s.prepare().unwrap();
        let count = AtomicU64::new(0);
        s.run(2, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panicking_task_surfaces_error() {
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        s.task(0).spawn();
        s.prepare().unwrap();
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence the backtrace
        let r = s.run(1, |_| panic!("boom"));
        std::panic::set_hook(hook);
        assert!(matches!(r, Err(SchedError::WorkerPanic)));
    }

    #[test]
    fn rerun_after_relearn() {
        let (mut s, _) = diamond(2);
        let count = AtomicU64::new(0);
        s.run(2, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        s.relearn_costs().unwrap();
        s.run(2, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 8, "scheduler is re-runnable");
    }

    #[test]
    fn timeline_recorded_when_enabled() {
        let (mut s, _) = diamond(1);
        let m = s.run(1, |_| {}).unwrap();
        assert_eq!(m.timeline.len(), 4);
        assert!(m.exec_ns > 0);
        let types: Vec<u32> = m.timeline.iter().map(|r| r.type_id).collect();
        assert_eq!(types[0], 0, "root task first");
    }
}
