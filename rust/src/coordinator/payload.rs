//! Typed task payloads.
//!
//! The paper's C API passes `void *data` and every kernel casts it back;
//! the seed reproduction transliterated that as raw little-endian byte
//! packing (`payload::from_i32s` / `from_u64s`). [`Payload`] replaces
//! both with typed encode/decode for the small POD values task graphs
//! actually carry — tile indices, cell ids, parameter tuples — so call
//! sites write `.payload(&(i, j, k))` and kernels `<(i32, i32, i32)>::
//! decode(view.data)` with the width checked at decode time.
//!
//! Implemented for the fixed-width scalars (`i32`, `u32`, `i64`, `u64`,
//! `f32`, `f64`), `usize` (always encoded as 8 bytes for a stable wire
//! format), `()` (empty payload) and tuples of up to four payloads.
//! Encoding is little-endian and identical to the deprecated
//! byte-packing helpers, so graphs built through either path carry
//! byte-identical task data (see `rust/tests/prop_typed_api.rs`).

/// A fixed-size POD value that can travel as a task's `data` bytes.
///
/// # Examples
///
/// ```
/// use quicksched::coordinator::Payload;
///
/// let enc = (3i32, 7i32, 2i32).encode();
/// assert_eq!(enc.len(), 12);
/// assert_eq!(<(i32, i32, i32)>::decode(&enc), (3, 7, 2));
/// ```
pub trait Payload: Sized {
    /// Encoded size in bytes.
    const SIZE: usize;

    /// Append the little-endian encoding of `self` to `out`.
    fn write_to(&self, out: &mut Vec<u8>);

    /// Read one value off the front of `data`, returning it and the
    /// remaining bytes. Panics if `data` is shorter than [`Self::SIZE`].
    fn read_from(data: &[u8]) -> (Self, &[u8]);

    /// Encode into a fresh byte vector of exactly [`Self::SIZE`] bytes.
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::SIZE);
        self.write_to(&mut out);
        debug_assert_eq!(out.len(), Self::SIZE);
        out
    }

    /// Decode from a task's payload bytes.
    ///
    /// # Panics
    /// If `data.len() != Self::SIZE` — a payload-type mismatch between
    /// the task's producer and its kernel is a bug, not a runtime
    /// condition.
    fn decode(data: &[u8]) -> Self {
        assert_eq!(
            data.len(),
            Self::SIZE,
            "payload size mismatch: task carries {} bytes, decoder expects {}",
            data.len(),
            Self::SIZE
        );
        Self::read_from(data).0
    }
}

macro_rules! scalar_payload {
    ($ty:ty, $n:expr) => {
        impl Payload for $ty {
            const SIZE: usize = $n;

            fn write_to(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }

            fn read_from(data: &[u8]) -> (Self, &[u8]) {
                let (head, rest) = data.split_at($n);
                (<$ty>::from_le_bytes(head.try_into().unwrap()), rest)
            }
        }
    };
}

scalar_payload!(i32, 4);
scalar_payload!(u32, 4);
scalar_payload!(i64, 8);
scalar_payload!(u64, 8);
scalar_payload!(f32, 4);
scalar_payload!(f64, 8);

/// `usize` always encodes as 8 bytes (via `u64`), matching the seed's
/// `from_u64s` packing of indices standing in for the paper's pointers.
impl Payload for usize {
    const SIZE: usize = 8;

    fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(*self as u64).to_le_bytes());
    }

    fn read_from(data: &[u8]) -> (Self, &[u8]) {
        let (v, rest) = u64::read_from(data);
        (v as usize, rest)
    }
}

/// The empty payload (tasks that need no parameters).
impl Payload for () {
    const SIZE: usize = 0;

    fn write_to(&self, _out: &mut Vec<u8>) {}

    fn read_from(data: &[u8]) -> (Self, &[u8]) {
        ((), data)
    }
}

macro_rules! tuple_payload {
    ($($name:ident),+) => {
        impl<$($name: Payload),+> Payload for ($($name,)+) {
            const SIZE: usize = 0 $(+ $name::SIZE)+;

            #[allow(non_snake_case)]
            fn write_to(&self, out: &mut Vec<u8>) {
                let ($($name,)+) = self;
                $($name.write_to(out);)+
            }

            #[allow(non_snake_case)]
            fn read_from(data: &[u8]) -> (Self, &[u8]) {
                $(let ($name, data) = $name::read_from(data);)+
                (($($name,)+), data)
            }
        }
    };
}

tuple_payload!(A);
tuple_payload!(A, B);
tuple_payload!(A, B, C);
tuple_payload!(A, B, C, D);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips() {
        assert_eq!(i32::decode(&(-7i32).encode()), -7);
        assert_eq!(u32::decode(&(9u32).encode()), 9);
        assert_eq!(i64::decode(&(i64::MIN).encode()), i64::MIN);
        assert_eq!(u64::decode(&(u64::MAX).encode()), u64::MAX);
        assert_eq!(usize::decode(&(42usize).encode()), 42);
        assert_eq!(f64::decode(&(1.5f64).encode()), 1.5);
        assert_eq!(f32::decode(&(0.25f32).encode()), 0.25);
    }

    #[test]
    fn unit_is_empty() {
        assert_eq!(().encode().len(), 0);
        <()>::decode(&[]);
    }

    #[test]
    fn tuples_roundtrip() {
        let p = (3i32, 7i32, 2i32);
        let enc = p.encode();
        assert_eq!(enc.len(), 12);
        assert_eq!(<(i32, i32, i32)>::decode(&enc), p);

        let q = (123usize, usize::MAX);
        assert_eq!(<(usize, usize)>::decode(&q.encode()), q);

        let mixed = (1u32, -2i64, 3.5f64, 4usize);
        assert_eq!(<(u32, i64, f64, usize)>::decode(&mixed.encode()), mixed);
    }

    #[test]
    #[allow(deprecated)]
    fn matches_legacy_byte_packing() {
        use super::super::task::payload;
        assert_eq!((3i32, -1i32, 1i32 << 30).encode(), payload::from_i32s(&[3, -1, 1 << 30]));
        assert_eq!((5usize, 9usize).encode(), payload::from_u64s(&[5, 9]));
    }

    #[test]
    #[should_panic(expected = "payload size mismatch")]
    fn decode_checks_width() {
        <(i32, i32)>::decode(&[0u8; 7]);
    }
}
