//! The kernel registry: task-type → kernel binding as *data*.
//!
//! The seed API made every runner re-implement a `match view.type_id`
//! closure at the call site; [`KernelRegistry`] binds each task type to
//! its kernel once per application, so the threaded executor
//! ([`Scheduler::run_registry`]), the virtual-time simulator
//! ([`Scheduler::run_sim_registry`]) and the server's persistent pool
//! (`crate::server::registry::JobGraph::from_registry`) all execute
//! through one registry lookup. Because the binding is a value, it can
//! be introspected (kernel names per type), validated against a graph
//! before running ([`KernelRegistry::validate`]) and — for the server —
//! declared by a template rather than hidden in a per-call closure.
//!
//! The registry also doubles as the simulation [`CostModel`]: each
//! entry may carry a per-type contention sensitivity (the Fig. 13
//! memory-bandwidth model) and the registry a global `ns_per_unit`
//! scale, so one object describes both *what a task type runs* and
//! *what it costs* on the modelled machine.
//!
//! # Examples
//!
//! ```
//! use std::sync::atomic::{AtomicI64, Ordering};
//! use quicksched::coordinator::{
//!     GraphBuilder, KernelRegistry, Payload, SchedConfig, Scheduler,
//! };
//!
//! let sum = AtomicI64::new(0);
//! let reg = KernelRegistry::new().bind(0u32, |view| {
//!     sum.fetch_add(i64::from(i32::decode(view.data)), Ordering::Relaxed);
//! });
//!
//! let mut sched = Scheduler::new(SchedConfig::new(1)).unwrap();
//! sched.task(0u32).payload(&21i32).spawn();
//! sched.task(0u32).payload(&21i32).spawn();
//! sched.prepare().unwrap();
//! sched.run_registry(1, &reg).unwrap();
//! assert_eq!(sum.load(Ordering::Relaxed), 42);
//! ```

use super::error::{Result, SchedError};
use super::metrics::RunMetrics;
use super::scheduler::Scheduler;
use super::sim::{CostModel, SimCtx};
use super::task::{TaskType, TaskView};

/// One bound kernel.
struct KernelEntry<'a> {
    name: &'static str,
    /// Memory-contention sensitivity of this task type (0.0 = fully
    /// compute-bound) for the simulation cost model.
    sensitivity: f64,
    exec: Box<dyn Fn(TaskView<'_>) + Send + Sync + 'a>,
}

/// Task-type → kernel map, built once per application (or per server
/// template instance) and shared by every executor. See the module docs
/// for an example.
///
/// The lifetime `'a` is the lifetime of state the kernels borrow; use
/// `KernelRegistry<'static>` (kernels capturing `Arc`s) where the
/// registry outlives the current stack frame, e.g. on the server.
pub struct KernelRegistry<'a> {
    /// Dense by type id.
    entries: Vec<Option<KernelEntry<'a>>>,
    /// Simulation time per unit of task cost (ns); see [`CostModel`].
    ns_per_unit: f64,
    /// Shared-L2 module count of the simulated machine; 0 disables the
    /// contention term.
    machine_modules: usize,
}

impl<'a> KernelRegistry<'a> {
    pub fn new() -> Self {
        Self {
            entries: Vec::new(),
            ns_per_unit: 1.0,
            machine_modules: 0,
        }
    }

    /// Bind task type `ty` to `kernel`, replacing any previous binding.
    ///
    /// # Panics
    /// If the type id is ≥ 65 536 — the registry is dense by type id,
    /// so a stray sentinel id (e.g. `-1` through the `i32` impl) must
    /// fail loudly instead of allocating billions of empty slots.
    pub fn bind<T: TaskType>(
        mut self,
        ty: T,
        kernel: impl Fn(TaskView<'_>) + Send + Sync + 'a,
    ) -> Self {
        let id = ty.type_id() as usize;
        assert!(
            id < (1 << 16),
            "task type id {id} out of range for the dense kernel registry (max 65535)"
        );
        if self.entries.len() <= id {
            self.entries.resize_with(id + 1, || None);
        }
        self.entries[id] = Some(KernelEntry {
            name: ty.type_name(),
            sensitivity: 0.0,
            exec: Box::new(kernel),
        });
        self
    }

    /// Set the simulated ns per unit of task cost (default 1.0).
    pub fn with_sim_scale(mut self, ns_per_unit: f64) -> Self {
        self.ns_per_unit = ns_per_unit;
        self
    }

    /// Enable the Fig. 13 memory-contention term: past `machine_modules`
    /// active cores, per-type-sensitive task durations inflate (cf.
    /// [`super::sim::ContentionCost`]).
    pub fn with_contention(mut self, machine_modules: usize) -> Self {
        self.machine_modules = machine_modules;
        self
    }

    /// Set the contention sensitivity of an already-bound task type.
    ///
    /// # Panics
    /// If `ty` has no kernel bound yet.
    pub fn with_sensitivity<T: TaskType>(mut self, ty: T, sensitivity: f64) -> Self {
        let id = ty.type_id() as usize;
        match self.entries.get_mut(id).and_then(Option::as_mut) {
            Some(e) => e.sensitivity = sensitivity,
            None => panic!("with_sensitivity({id}): no kernel bound for that type"),
        }
        self
    }

    /// Whether `type_id` has a kernel bound.
    pub fn is_bound(&self, type_id: u32) -> bool {
        matches!(self.entries.get(type_id as usize), Some(Some(_)))
    }

    /// Kernel name bound to `type_id`, if any (introspection: the server
    /// reports these per template).
    pub fn name_of(&self, type_id: u32) -> Option<&'static str> {
        self.entries
            .get(type_id as usize)
            .and_then(Option::as_ref)
            .map(|e| e.name)
    }

    /// `(type_id, kernel name)` of every binding, in type-id order.
    pub fn bindings(&self) -> Vec<(u32, &'static str)> {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (i as u32, e.name)))
            .collect()
    }

    /// Execute the kernel bound to `view`'s task type.
    ///
    /// # Panics
    /// If the type is unbound — kernels have no error channel, and the
    /// executors surface the panic as [`SchedError::WorkerPanic`]. Run
    /// through [`Scheduler::run_registry`] to get this checked up front
    /// instead.
    pub fn dispatch(&self, view: TaskView<'_>) {
        match self.entries.get(view.type_id as usize).and_then(Option::as_ref) {
            Some(e) => (e.exec)(view),
            None => panic!(
                "no kernel bound for task type {} (task {})",
                view.type_id, view.tid
            ),
        }
    }

    /// Check that every non-virtual task in `sched` has a kernel bound.
    pub fn validate(&self, sched: &Scheduler) -> Result<()> {
        for i in 0..sched.nr_tasks() {
            let (type_id, virtual_task) = sched.task_kind(super::task::TaskId(i as u32));
            if !virtual_task && !self.is_bound(type_id) {
                return Err(SchedError::UnboundTaskType(type_id));
            }
        }
        Ok(())
    }
}

impl Default for KernelRegistry<'_> {
    fn default() -> Self {
        Self::new()
    }
}

/// A registry is also a simulation cost model: `duration = cost ×
/// ns_per_unit × (1 + sensitivity(type) × shared_fraction)`, with the
/// contention ramp of [`super::sim::ContentionCost`] when
/// `machine_modules > 0`.
impl CostModel for KernelRegistry<'_> {
    fn duration_ns(&self, view: TaskView<'_>, ctx: &SimCtx) -> u64 {
        let base = (view.cost.max(1) as f64) * self.ns_per_unit;
        let inflated = if self.machine_modules > 0 {
            let modules = self.machine_modules as f64;
            let shared = ((ctx.active_cores as f64 - modules) / modules).clamp(0.0, 1.0);
            let s = self
                .entries
                .get(view.type_id as usize)
                .and_then(Option::as_ref)
                .map_or(0.0, |e| e.sensitivity);
            base * (1.0 + s * shared)
        } else {
            base
        };
        inflated.max(1.0) as u64
    }
}

impl Scheduler {
    /// `qsched_run` through a [`KernelRegistry`]: validates that every
    /// task type is bound, then executes on `nr_threads` workers via one
    /// registry lookup per task.
    pub fn run_registry(
        &mut self,
        nr_threads: usize,
        registry: &KernelRegistry<'_>,
    ) -> Result<RunMetrics> {
        registry.validate(self)?;
        self.run(nr_threads, |view| registry.dispatch(view))
    }

    /// Virtual-time execution with the registry as the [`CostModel`]
    /// (per-type sensitivities + global scale). Validates bindings so a
    /// sim-only misconfiguration fails the same way a real run would.
    pub fn run_sim_registry(
        &mut self,
        nr_cores: usize,
        registry: &KernelRegistry<'_>,
    ) -> Result<RunMetrics> {
        registry.validate(self)?;
        self.run_sim(nr_cores, registry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::builder::GraphBuilder;
    use crate::coordinator::{Payload, SchedConfig};
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn dispatch_routes_by_type() {
        let a = AtomicU64::new(0);
        let b = AtomicU64::new(0);
        let reg = KernelRegistry::new()
            .bind(0u32, |_| {
                a.fetch_add(1, Ordering::Relaxed);
            })
            .bind(3u32, |view| {
                b.fetch_add(u64::from(u32::decode(view.data)), Ordering::Relaxed);
            });
        assert!(reg.is_bound(0) && reg.is_bound(3));
        assert!(!reg.is_bound(1));
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        s.task(0u32).spawn();
        s.task(3u32).payload(&5u32).spawn();
        s.task(3u32).payload(&7u32).spawn();
        s.prepare().unwrap();
        let m = s.run_registry(1, &reg).unwrap();
        assert_eq!(m.tasks_run, 3);
        assert_eq!(a.load(Ordering::Relaxed), 1);
        assert_eq!(b.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn unbound_type_rejected_up_front() {
        let reg = KernelRegistry::new().bind(0u32, |_| {});
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        s.task(9u32).spawn();
        s.prepare().unwrap();
        assert!(matches!(
            s.run_registry(1, &reg),
            Err(SchedError::UnboundTaskType(9))
        ));
        assert!(matches!(
            s.run_sim_registry(1, &reg),
            Err(SchedError::UnboundTaskType(9))
        ));
    }

    #[test]
    fn virtual_tasks_need_no_kernel() {
        let reg = KernelRegistry::new().bind(0u32, |_| {});
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        let v = s.task(7u32).virtual_task().spawn();
        s.task(0u32).after([v]).spawn();
        s.prepare().unwrap();
        let m = s.run_registry(1, &reg).unwrap();
        assert_eq!(m.tasks_run, 1);
    }

    #[test]
    fn introspection_reports_bindings() {
        let reg = KernelRegistry::new().bind(2u32, |_| {}).bind(0u32, |_| {});
        assert_eq!(reg.name_of(2), Some("task"));
        assert_eq!(reg.name_of(1), None);
        let b = reg.bindings();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0, 0);
        assert_eq!(b[1].0, 2);
    }

    #[test]
    fn registry_as_cost_model() {
        let reg = KernelRegistry::new()
            .bind(0u32, |_| {})
            .with_sim_scale(10.0);
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        for _ in 0..4 {
            s.task(0u32).cost(25).spawn();
        }
        s.prepare().unwrap();
        let m = s.run_sim_registry(1, &reg).unwrap();
        // 4 × 25 units × 10 ns/unit + 4 × 250 ns gettask overhead.
        assert_eq!(m.elapsed_ns, 4 * 250 + 4 * 250);
    }

    #[test]
    fn contention_inflates_busy_machines() {
        let busy = KernelRegistry::new()
            .bind(0u32, |_| {})
            .with_contention(2)
            .with_sensitivity(0u32, 0.5);
        let view_cost = |active: usize| {
            // Build a throwaway scheduler to get a TaskView.
            let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
            let t = s.task(0u32).cost(1000).spawn();
            s.prepare().unwrap();
            let ctx = SimCtx { now_ns: 0, active_cores: active, nr_cores: 4 };
            busy.duration_ns(s.task_view(t), &ctx)
        };
        assert_eq!(view_cost(1), 1000, "under-subscribed: no inflation");
        assert_eq!(view_cost(4), 1500, "fully shared: +sensitivity");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bind_rejects_huge_type_id() {
        // A sentinel id (e.g. -1 as u32) must fail loudly, not allocate
        // billions of empty dense slots.
        let _ = KernelRegistry::new().bind(u32::MAX, |_view: TaskView<'_>| {});
    }

    #[test]
    #[should_panic(expected = "no kernel bound")]
    fn dispatch_panics_on_unbound() {
        let reg = KernelRegistry::new();
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        let t = s.task(1u32).spawn();
        s.prepare().unwrap();
        reg.dispatch(s.task_view(t));
    }
}
