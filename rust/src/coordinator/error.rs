//! Scheduler error types.

use thiserror::Error;

/// Errors surfaced while building or running a task graph.
#[derive(Debug, Error)]
pub enum SchedError {
    /// A dependency cycle was found during weight computation (§3.1 computes
    /// weights in reverse topological order, which requires a DAG).
    #[error("dependency cycle detected involving {ntasks} task(s); first task in cycle: {example}")]
    Cycle { ntasks: usize, example: u32 },

    /// A task handle did not belong to this scheduler.
    #[error("task handle {0} out of range ({1} tasks)")]
    BadTask(u32, usize),

    /// A resource handle did not belong to this scheduler.
    #[error("resource handle {0} out of range ({1} resources)")]
    BadRes(u32, usize),

    /// A self-dependency (task unlocking itself) was requested.
    #[error("task {0} cannot depend on itself")]
    SelfDependency(u32),

    /// The scheduler was run before `prepare()` / after a failed build.
    #[error("scheduler not prepared: {0}")]
    NotPrepared(&'static str),

    /// No queues configured.
    #[error("scheduler needs at least one queue (got {0})")]
    NoQueues(usize),

    /// A worker panicked while executing a task.
    #[error("worker thread panicked while executing tasks")]
    WorkerPanic,

    /// A task spec locked the same resource twice (build-time check of
    /// the typed `TaskSpec` API).
    #[error("task spec locks resource {0} more than once")]
    DuplicateLock(u32),

    /// A task spec requested locks on a virtual task — virtual tasks
    /// never execute, so their locks would be silently ignored.
    #[error("virtual task cannot lock resources ({0} locks requested)")]
    VirtualTaskLocks(usize),

    /// A graph was run through a `KernelRegistry` missing a binding for
    /// one of its task types.
    #[error("no kernel bound for task type {0}")]
    UnboundTaskType(u32),

    /// The graph's flattened arenas would not fit the `u32` span
    /// address space of the compiled CSR layout.
    #[error("graph exceeds the u32 arena address space ({adj} adjacency entries, {payload} payload bytes)")]
    GraphTooLarge { adj: usize, payload: usize },
}

pub type Result<T> = std::result::Result<T, SchedError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_render() {
        let e = SchedError::Cycle { ntasks: 3, example: 7 };
        assert!(e.to_string().contains("cycle"));
        assert!(SchedError::BadTask(9, 2).to_string().contains('9'));
        assert!(SchedError::SelfDependency(1).to_string().contains("itself"));
    }
}
