//! Virtual-time executor: the hardware substitution for the paper's
//! 64-core Opteron (see DESIGN.md §Hardware-substitutions).
//!
//! This is a discrete-event simulation over *N virtual cores* that runs
//! the **real** scheduler code — the same `start`/`gettask`/`complete`
//! paths, the same max-heap queues, the same hierarchical resource
//! lock/hold protocol — but advances a virtual clock instead of burning
//! wall time. Task durations come from a [`CostModel`] calibrated against
//! single-core measurements of the real kernels, so strong-scaling
//! curves, critical-path effects, conflict serialization and overhead
//! fractions reproduce the *shape* of the paper's figures on a machine
//! with any number of physical cores (ours has one).
//!
//! Determinism: given the same graph, cost model and seed, the simulation
//! is bit-reproducible — idle cores poll in core order, events tie-break
//! on (time, core).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::error::Result;
use super::metrics::{RunMetrics, TimelineRecord};
use super::scheduler::Scheduler;
use super::task::{TaskId, TaskView};
use crate::util::rng::Rng;

/// Context handed to the cost model at dispatch time.
#[derive(Clone, Copy, Debug)]
pub struct SimCtx {
    /// Virtual time of dispatch, ns.
    pub now_ns: u64,
    /// Number of cores busy at dispatch (including the dispatching one).
    pub active_cores: usize,
    /// Total virtual cores in the simulation.
    pub nr_cores: usize,
}

/// Maps a task to its virtual duration. Implementations model the
/// *hardware*, not the scheduler: the scheduler's own behaviour (queue
/// order, lock conflicts, stealing) is simulated exactly.
pub trait CostModel: Sync {
    /// Virtual execution time of `view` in ns.
    fn duration_ns(&self, view: TaskView<'_>, ctx: &SimCtx) -> u64;

    /// Virtual overhead of a successful `gettask`, ns. The paper measures
    /// this (Fig. 13) at well under 1% of task runtime; the default of
    /// 250 ns matches our measured `gettask` hot path (see EXPERIMENTS.md
    /// §Perf).
    fn gettask_overhead_ns(&self, _view: TaskView<'_>, stolen: bool) -> u64 {
        if stolen {
            600
        } else {
            250
        }
    }
}

/// Duration = `task.cost` ns. The simplest calibration: costs already are
/// (or are proportional to) nanoseconds.
pub struct UnitCost;

impl CostModel for UnitCost {
    fn duration_ns(&self, view: TaskView<'_>, _ctx: &SimCtx) -> u64 {
        view.cost.max(1) as u64
    }
}

/// Duration = `task.cost * ns_per_cost` — costs in abstract units (e.g.
/// flop counts) scaled by a measured per-unit time.
pub struct ScaledCost {
    pub ns_per_cost: f64,
}

impl CostModel for ScaledCost {
    fn duration_ns(&self, view: TaskView<'_>, _ctx: &SimCtx) -> u64 {
        ((view.cost.max(1) as f64) * self.ns_per_cost).max(1.0) as u64
    }
}

/// Memory-bandwidth contention model for Fig. 13: the simulated machine
/// (the paper's 64-core Opteron 6376) pairs cores on a shared 2 MB L2 —
/// 32 modules. While ≤ 32 cores are active, every core effectively has
/// its own L2; past that, pairs share, and memory-bound task types slow
/// down (the paper measures +30–40% for pair interactions, +10% for the
/// compute-dense particle–cell tasks).
///
/// `duration = base * (1 + sensitivity(type) * shared_fraction)` where
/// `shared_fraction` ramps 0→1 as the *absolute* number of active cores
/// goes from `machine_modules` (32) to `2 × machine_modules` (64) —
/// a property of the machine, not of how many cores the run uses.
pub struct ContentionCost<M: CostModel> {
    pub base: M,
    /// `sensitivity[type_id]`, e.g. 0.35 for particle-pair tasks.
    pub sensitivity: Vec<f64>,
    /// Number of shared-L2 modules on the modelled machine (Opteron
    /// 6376: 32).
    pub machine_modules: usize,
}

impl<M: CostModel> CostModel for ContentionCost<M> {
    fn duration_ns(&self, view: TaskView<'_>, ctx: &SimCtx) -> u64 {
        let base = self.base.duration_ns(view, ctx);
        let modules = self.machine_modules as f64;
        let shared = ((ctx.active_cores as f64 - modules) / modules).clamp(0.0, 1.0);
        let s = self
            .sensitivity
            .get(view.type_id as usize)
            .copied()
            .unwrap_or(0.0);
        (base as f64 * (1.0 + s * shared)).round() as u64
    }

    fn gettask_overhead_ns(&self, view: TaskView<'_>, stolen: bool) -> u64 {
        self.base.gettask_overhead_ns(view, stolen)
    }
}

/// Completion event in the virtual-time queue.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Event {
    finish_ns: u64,
    core: usize,
    tid: TaskId,
}

impl Scheduler {
    /// Execute the task graph on `nr_cores` *virtual* cores under the
    /// given cost model, returning the same [`RunMetrics`] the threaded
    /// executor produces (with virtual times). Core *i* prefers queue
    /// `i % nr_queues`, exactly like the threaded workers.
    pub fn run_sim<M: CostModel>(&mut self, nr_cores: usize, model: &M) -> Result<RunMetrics> {
        assert!(nr_cores > 0, "need at least one virtual core");
        self.start()?;
        let record = self.config.record_timeline;
        let mut rng = Rng::new(self.config.seed);
        let nq = self.nr_queues();
        let mut events: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut busy = vec![false; nr_cores];
        let mut active = 0usize;
        let mut now = 0u64;
        let mut metrics = RunMetrics {
            workers: nr_cores,
            ..Default::default()
        };
        // Per-core stamp of when the core last went idle, to account
        // gettask/idle time like the threaded executor does.
        let mut idle_since = vec![0u64; nr_cores];

        loop {
            // Dispatch phase: one pass over the idle cores (§Perf opt D:
            // a single pass suffices — acquisitions only *remove* queue
            // entries and *take* resource locks, so a core that failed
            // earlier in the pass cannot succeed later in the same pass;
            // queue contents only change again on the next completion).
            // Skip the pass entirely while nothing is queued.
            {
                for core in 0..nr_cores {
                    if self.queued_hint() == 0 {
                        break;
                    }
                    if busy[core] {
                        continue;
                    }
                    let qid = core % nq;
                    if let Some((tid, stolen)) = self.gettask(qid, &mut rng) {
                        let view = self.task_view(tid);
                        active += 1;
                        let ctx = SimCtx { now_ns: now, active_cores: active, nr_cores };
                        let get_ns = model.gettask_overhead_ns(view, stolen);
                        let dur = model.duration_ns(view, &ctx).max(1);
                        let start = now + get_ns;
                        let finish = start + dur;
                        busy[core] = true;
                        metrics.tasks_run += 1;
                        metrics.tasks_stolen += stolen as usize;
                        metrics.gettask_ns += get_ns;
                        metrics.idle_ns += now - idle_since[core];
                        metrics.exec_ns += dur;
                        if record {
                            metrics.timeline.push(TimelineRecord {
                                tid,
                                type_id: view.type_id,
                                worker: core as u32,
                                start_ns: start,
                                end_ns: finish,
                                get_ns,
                                stolen,
                            });
                        }
                        events.push(Reverse(Event { finish_ns: finish, core, tid }));
                    }
                }
            }
            // Advance to the next completion.
            match events.pop() {
                Some(Reverse(Event { finish_ns, core, tid })) => {
                    now = finish_ns;
                    busy[core] = false;
                    idle_since[core] = now;
                    active -= 1;
                    self.complete(tid);
                }
                None => break,
            }
        }
        debug_assert_eq!(self.waiting(), 0, "sim finished with tasks pending");
        debug_assert!(self.res.all_quiescent(), "sim leaked resource locks");
        metrics.elapsed_ns = now;
        metrics
            .timeline
            .sort_unstable_by_key(|r| (r.start_ns, r.worker));
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::builder::GraphBuilder;
    use crate::coordinator::config::SchedConfig;

    fn chain(n: usize, cost: i64, nq: usize) -> Scheduler {
        let mut s = Scheduler::new(SchedConfig::new(nq).with_timeline(true)).unwrap();
        let mut prev = None;
        for _ in 0..n {
            let t = s.task(0).cost(cost).spawn();
            if let Some(p) = prev {
                s.add_unlock(p, t);
            }
            prev = Some(t);
        }
        s.prepare().unwrap();
        s
    }

    fn independent(n: usize, cost: i64, nq: usize) -> Scheduler {
        let mut s = Scheduler::new(SchedConfig::new(nq).with_timeline(true)).unwrap();
        for _ in 0..n {
            s.task(0).cost(cost).spawn();
        }
        s.prepare().unwrap();
        s
    }

    struct NoOverhead;
    impl CostModel for NoOverhead {
        fn duration_ns(&self, view: TaskView<'_>, _: &SimCtx) -> u64 {
            view.cost.max(1) as u64
        }
        fn gettask_overhead_ns(&self, _: TaskView<'_>, _: bool) -> u64 {
            0
        }
    }

    #[test]
    fn chain_is_serial() {
        let mut s = chain(10, 100, 4);
        let m = s.run_sim(4, &NoOverhead).unwrap();
        assert_eq!(m.elapsed_ns, 1000, "a chain cannot parallelize");
        assert_eq!(m.tasks_run, 10);
    }

    #[test]
    fn independent_tasks_scale_perfectly() {
        let mut s = independent(64, 100, 4);
        let m = s.run_sim(4, &NoOverhead).unwrap();
        assert_eq!(m.elapsed_ns, 64 * 100 / 4);
        assert!(m.check_no_worker_overlap());
        let mut s1 = independent(64, 100, 1);
        let m1 = s1.run_sim(1, &NoOverhead).unwrap();
        assert_eq!(m1.elapsed_ns, 6400);
        assert!((m.parallel_efficiency(m1.elapsed_ns) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn conflicts_serialize_in_virtual_time() {
        // 8 tasks locking one resource on 8 cores: elapsed == serial.
        let mut s = Scheduler::new(SchedConfig::new(8).with_timeline(true)).unwrap();
        let r = s.add_resource(None, -1);
        for _ in 0..8 {
            let t = s.task(0).cost(50).spawn();
            s.add_lock(t, r);
        }
        s.prepare().unwrap();
        let m = s.run_sim(8, &NoOverhead).unwrap();
        assert_eq!(m.elapsed_ns, 400, "conflicting tasks must serialize");
        // And the timeline must show no overlap between any two records
        // (they all lock the same resource).
        let mut iv: Vec<(u64, u64)> =
            m.timeline.iter().map(|r| (r.start_ns, r.end_ns)).collect();
        iv.sort_unstable();
        for w in iv.windows(2) {
            assert!(w[1].0 >= w[0].1);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut s = Scheduler::new(
                SchedConfig::new(4).with_seed(123).with_timeline(true),
            )
            .unwrap();
            let r = s.add_resource(None, -1);
            for i in 0..40 {
                let t = s.task(i % 3).cost(10 + i as i64).spawn();
                if i % 5 == 0 {
                    s.add_lock(t, r);
                }
            }
            s.prepare().unwrap();
            let m = s.run_sim(4, &UnitCost).unwrap();
            (
                m.elapsed_ns,
                m.tasks_stolen,
                m.timeline
                    .iter()
                    .map(|r| (r.tid.0, r.worker, r.start_ns))
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run(), "sim must be bit-deterministic");
    }

    #[test]
    fn critical_path_lower_bounds_elapsed() {
        let mut s = chain(5, 100, 2);
        // add parallel side work
        for _ in 0..10 {
            s.task(0).cost(30).spawn();
        }
        s.prepare().unwrap();
        let cp = s.critical_path() as u64;
        let m = s.run_sim(2, &NoOverhead).unwrap();
        assert!(m.elapsed_ns >= cp);
    }

    #[test]
    fn weighted_scheduling_beats_fifo_on_critical_path() {
        // Workload where critical-path scheduling matters: one long chain
        // plus many independent fillers. CriticalPath keys should finish
        // no later than Fifo keys.
        let build = |policy| {
            let mut cfg = SchedConfig::new(4).with_seed(7);
            cfg.flags.key_policy = policy;
            let mut s = Scheduler::new(cfg).unwrap();
            // filler first so FIFO prefers it
            for _ in 0..32 {
                s.task(1).cost(100).spawn();
            }
            let mut prev = None;
            for _ in 0..16 {
                let t = s.task(0).cost(100).spawn();
                if let Some(p) = prev {
                    s.add_unlock(p, t);
                }
                prev = Some(t);
            }
            s.prepare().unwrap();
            s
        };
        use crate::coordinator::config::KeyPolicy;
        let mut s_cp = build(KeyPolicy::CriticalPath);
        let mut s_ff = build(KeyPolicy::Fifo);
        let t_cp = s_cp.run_sim(4, &NoOverhead).unwrap().elapsed_ns;
        let t_ff = s_ff.run_sim(4, &NoOverhead).unwrap().elapsed_ns;
        assert!(
            t_cp <= t_ff,
            "critical-path keys ({t_cp}) must not lose to FIFO ({t_ff})"
        );
        // The chain (1600) dominates; CP should be near-optimal.
        assert!(t_cp <= 1700, "t_cp={t_cp}");
    }

    #[test]
    fn contention_model_inflates_busy_machines() {
        let model = ContentionCost {
            base: UnitCost,
            sensitivity: vec![0.4],
            machine_modules: 4, // 8-core machine, 4 shared modules
        };
        let mut s = independent(32, 1000, 8);
        let m8 = s.run_sim(8, &model).unwrap();
        let mut s1 = independent(32, 1000, 1);
        let m1 = s1.run_sim(1, &model).unwrap();
        // With all 8 cores busy the per-task time inflates up to 40%.
        let speedup = m1.elapsed_ns as f64 / m8.elapsed_ns as f64;
        assert!(speedup < 8.0, "contention must cost something: {speedup}");
        assert!(speedup > 4.0, "but not everything: {speedup}");
    }

    #[test]
    fn gettask_overhead_accounted() {
        let mut s = independent(10, 100, 1);
        let m = s.run_sim(1, &UnitCost).unwrap();
        assert!(m.gettask_ns >= 10 * 250);
        assert!(m.overhead_fraction() > 0.0);
    }
}
