//! Graph-construction abstraction: the two applications (QR, Barnes-Hut)
//! emit their task graphs through this trait, so the same generator can
//! target the real [`Scheduler`] or the dependency-only baseline
//! ([`crate::baselines::DepOnlyBuilder`]) for the Fig. 8/11 comparisons.

use super::resource::ResId;
use super::scheduler::{ResHandle, Scheduler, TaskHandle};
use super::task::TaskFlags;

pub trait GraphBuilder {
    fn add_task(&mut self, type_id: u32, data: &[u8], cost: i64) -> TaskHandle;
    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle;
    fn add_lock(&mut self, t: TaskHandle, r: ResId);
    fn add_use(&mut self, t: TaskHandle, r: ResId);
    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle);
    fn nr_queues(&self) -> usize;
}

impl GraphBuilder for Scheduler {
    fn add_task(&mut self, type_id: u32, data: &[u8], cost: i64) -> TaskHandle {
        Scheduler::add_task(self, type_id, TaskFlags::default(), data, cost)
    }

    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle {
        Scheduler::add_resource(self, parent, owner)
    }

    fn add_lock(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_lock(self, t, r)
    }

    fn add_use(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_use(self, t, r)
    }

    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        Scheduler::add_unlock(self, ta, tb)
    }

    fn nr_queues(&self) -> usize {
        Scheduler::nr_queues(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SchedConfig;

    #[test]
    fn scheduler_implements_builder() {
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let b: &mut dyn GraphBuilder = &mut s;
        let r = b.add_resource(None, 0);
        let t0 = b.add_task(0, &[], 1);
        let t1 = b.add_task(1, &[], 2);
        b.add_lock(t0, r);
        b.add_use(t1, r);
        b.add_unlock(t0, t1);
        assert_eq!(b.nr_queues(), 2);
        s.prepare().unwrap();
        assert_eq!(s.stats().tasks, 2);
        assert_eq!(s.stats().dependencies, 1);
    }
}
