//! Graph construction: the build-side abstraction and the freeze into
//! the flat CSR/SoA layout.
//!
//! Two things live here:
//!
//! * [`GraphBuilder`] — the trait the applications (QR, Cholesky,
//!   Barnes-Hut) emit their task graphs through, so the same generator
//!   can target the real [`Scheduler`] or the dependency-only baseline
//!   ([`crate::baselines::DepOnlyBuilder`]) for the Fig. 8/11
//!   comparisons. Graphs are built through the typed [`TaskSpec`] entry
//!   point ([`GraphBuilder::task`]); the untyped byte-payload
//!   [`GraphBuilder::add_task`] remains as a deprecated shim.
//! * [`CompiledGraph::freeze`] / [`CompiledGraph::thaw`] — the boundary
//!   between the builder's per-task `Vec`s and the frozen arena layout
//!   the runtime consumes (see `compiled.rs`). This module is
//!   deliberately the *only* place task adjacency `Vec`s are iterated;
//!   every runtime consumer goes through the span accessors on
//!   [`CompiledGraph`].

use std::sync::Arc;

use super::compiled::{CompiledGraph, FrozenGraph, Span, TaskRunState};
use super::error::{Result, SchedError};
use super::graph::GraphStats;
use super::resource::{ResId, ResTable};
use super::scheduler::{ResHandle, Scheduler, TaskHandle};
use super::spec::TaskSpec;
use super::task::{Task, TaskFlags, TaskType};
use super::weights::compute_weights;

pub trait GraphBuilder {
    /// Emit one task with explicit flags and owned payload bytes — the
    /// primitive [`TaskSpec::spawn`] lowers to. Application code should
    /// use [`GraphBuilder::task`] instead.
    fn raw_task(&mut self, type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> TaskHandle;

    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle;
    fn add_lock(&mut self, t: TaskHandle, r: ResId);
    fn add_use(&mut self, t: TaskHandle, r: ResId);
    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle);
    fn nr_queues(&self) -> usize;

    /// Tasks emitted so far (spec validation of `after` handles).
    fn nr_tasks_built(&self) -> usize;

    /// Resources emitted so far (spec validation of `lock`/`use`
    /// handles).
    fn nr_resources_built(&self) -> usize;

    /// Start a typed [`TaskSpec`] for a task of type `ty`:
    /// `b.task(QrTask::Geqrf).payload(&(i, j, k)).cost(c).lock(r).spawn()`.
    fn task<T: TaskType>(&mut self, ty: T) -> TaskSpec<'_, Self>
    where
        Self: Sized,
    {
        TaskSpec::new(self, ty.type_id())
    }

    /// The legacy untyped build call (`qsched_addtask` with pre-packed
    /// payload bytes), kept so out-of-tree callers and the
    /// paper-fidelity tests compile unchanged.
    #[deprecated(
        since = "0.3.0",
        note = "build through the typed TaskSpec API: `b.task(ty).payload(&…).cost(c).spawn()`"
    )]
    fn add_task(&mut self, type_id: u32, data: &[u8], cost: i64) -> TaskHandle {
        self.raw_task(type_id, TaskFlags::default(), data.to_vec(), cost)
    }
}

impl GraphBuilder for Scheduler {
    fn raw_task(&mut self, type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> TaskHandle {
        self.push_task(type_id, flags, data, cost)
    }

    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle {
        Scheduler::add_resource(self, parent, owner)
    }

    fn add_lock(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_lock(self, t, r)
    }

    fn add_use(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_use(self, t, r)
    }

    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        Scheduler::add_unlock(self, ta, tb)
    }

    fn nr_queues(&self) -> usize {
        Scheduler::nr_queues(self)
    }

    fn nr_tasks_built(&self) -> usize {
        self.nr_tasks()
    }

    fn nr_resources_built(&self) -> usize {
        self.nr_resources()
    }
}

// ----------------------------------------------------------------------
// The freeze: builder Vec<Task> → CSR/SoA CompiledGraph
// ----------------------------------------------------------------------

impl CompiledGraph {
    /// Compile the builder's task records into the flat layout:
    /// validate handles, sort + dedup each task's lock set (dropping
    /// locks subsumed by a locked hierarchical ancestor — the §3.3
    /// discipline), lay all adjacency lists into one `u32` arena and
    /// all payloads into one byte arena, precompute initial wait counts
    /// and the root list, and compute critical-path weights (which also
    /// detects cycles).
    ///
    /// The builder records are only *read*; on error (bad handle,
    /// cycle) the caller's build state is untouched.
    pub fn freeze(tasks: &[Task], res: &ResTable) -> Result<Self> {
        let n = tasks.len();
        let nr = res.len();
        // Structural validation before any copying: every handle in
        // range, no self-dependencies. (Duplicate unlock edges are
        // legal in the paper's C code — they double-decrement — and
        // pass through unchanged.)
        for (i, t) in tasks.iter().enumerate() {
            for u in &t.unlocks {
                if u.idx() >= n {
                    return Err(SchedError::BadTask(u.0, n));
                }
                if u.idx() == i {
                    return Err(SchedError::SelfDependency(i as u32));
                }
            }
            for r in t.locks.iter().chain(t.uses.iter()) {
                if r.idx() >= nr {
                    return Err(SchedError::BadRes(r.0, nr));
                }
            }
        }

        let total_adj: usize = tasks
            .iter()
            .map(|t| t.unlocks.len() + t.locks.len() + t.uses.len())
            .sum();
        let total_data: usize = tasks.iter().map(|t| t.data.len()).sum();
        if total_adj > u32::MAX as usize || total_data > u32::MAX as usize {
            return Err(SchedError::GraphTooLarge { adj: total_adj, payload: total_data });
        }
        let mut adj: Vec<u32> = Vec::with_capacity(total_adj);
        let mut payload: Vec<u8> = Vec::with_capacity(total_data);
        let mut unlocks = Vec::with_capacity(n);
        let mut locks = Vec::with_capacity(n);
        let mut uses = Vec::with_capacity(n);
        let mut data = Vec::with_capacity(n);
        let mut type_id = Vec::with_capacity(n);
        let mut virtual_flag = Vec::with_capacity(n);
        let mut cost = Vec::with_capacity(n);
        let mut scratch: Vec<ResId> = Vec::new();

        let span_from = |start: usize, end: usize| Span {
            off: start as u32,
            len: (end - start) as u32,
        };

        for t in tasks {
            // Unlocks: copied verbatim (order and multiplicity are
            // user-visible through the wait-count semantics).
            let start = adj.len();
            adj.extend(t.unlocks.iter().map(|u| u.0));
            unlocks.push(span_from(start, adj.len()));

            // Locks: sort by resource id (§3.3 dining-philosophers
            // fix), dedup, then drop any lock whose hierarchical
            // *ancestor* is also locked by this task — the ancestor
            // lock already excludes the whole subtree, and attempting
            // both would self-deadlock.
            scratch.clear();
            scratch.extend_from_slice(&t.locks);
            scratch.sort_unstable();
            scratch.dedup();
            if scratch.len() > 1 {
                let lock_set = scratch.clone();
                scratch.retain(|&r| {
                    let mut up = res.get(r).parent;
                    while let Some(p) = up {
                        if lock_set.binary_search(&p).is_ok() {
                            return false;
                        }
                        up = res.get(p).parent;
                    }
                    true
                });
            }
            let start = adj.len();
            adj.extend(scratch.iter().map(|r| r.0));
            locks.push(span_from(start, adj.len()));

            // Uses: sorted + deduped (affinity hints; multiplicity
            // would only skew the enqueue scoring).
            scratch.clear();
            scratch.extend_from_slice(&t.uses);
            scratch.sort_unstable();
            scratch.dedup();
            let start = adj.len();
            adj.extend(scratch.iter().map(|r| r.0));
            uses.push(span_from(start, adj.len()));

            let start = payload.len();
            payload.extend_from_slice(&t.data);
            data.push(Span { off: start as u32, len: t.data.len() as u32 });

            type_id.push(t.type_id);
            virtual_flag.push(t.flags.virtual_task);
            cost.push(t.cost.max(1));
        }

        // Initial wait counts (in-degree) + roots, so `start()` is a
        // plain store per task instead of an O(edges) atomic re-count.
        let mut wait0 = vec![0i32; n];
        for t in tasks {
            for u in &t.unlocks {
                wait0[u.idx()] += 1;
            }
        }
        let roots: Vec<u32> = (0..n as u32).filter(|&i| wait0[i as usize] == 0).collect();

        let meta = FrozenGraph {
            n,
            adj,
            payload,
            unlocks,
            locks,
            uses,
            data,
            type_id,
            virtual_flag,
            wait0,
            roots,
        };
        let run: Box<[TaskRunState]> = tasks
            .iter()
            .map(|t| {
                let r = TaskRunState::new();
                // Seed the learned snapshot so timings survive a
                // thaw → rebuild → re-freeze cycle (see `Task::learned_ns`).
                if t.learned_ns > 0 {
                    r.learned_ns
                        .store(t.learned_ns, std::sync::atomic::Ordering::Relaxed);
                }
                r
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let mut g = CompiledGraph { meta: Arc::new(meta), cost, weight: vec![0; n], run };
        compute_weights(&mut g)?;
        Ok(g)
    }

    /// Reconstitute builder-side task records from the frozen layout —
    /// the reverse of [`CompiledGraph::freeze`], used when a caller
    /// resumes *building* after a `prepare()` (the lock sets come back
    /// sorted/subsumed, which is semantically equivalent; costs carry
    /// any relearning that happened in between).
    pub fn thaw(&self) -> Vec<Task> {
        (0..self.meta.n)
            .map(|i| {
                let mut t = Task::new(
                    self.type_id(i),
                    TaskFlags { virtual_task: self.is_virtual(i) },
                    self.data(i).to_vec(),
                    self.cost(i),
                );
                t.unlocks = self.unlock_ids(i).iter().map(|&u| super::task::TaskId(u)).collect();
                t.locks = self.lock_ids(i).iter().map(|&r| ResId(r)).collect();
                t.uses = self.use_ids(i).iter().map(|&r| ResId(r)).collect();
                // Preserve timings across the thaw: prefer the live
                // measurement of the most recent run, falling back to
                // the learned snapshot (mirrors `relearn_costs`).
                let ord = std::sync::atomic::Ordering::Relaxed;
                let measured = self.run[i].measured_ns.load(ord);
                t.learned_ns =
                    if measured > 0 { measured } else { self.run[i].learned_ns.load(ord) };
                t
            })
            .collect()
    }
}

impl GraphStats {
    /// Stats of a graph still under construction (pre-freeze). The
    /// frozen counterpart is [`GraphStats::of_compiled`]; counts agree
    /// up to the lock/use dedup the freeze performs.
    pub fn of(tasks: &[Task], res: &ResTable) -> Self {
        let mut s = Self {
            tasks: tasks.len(),
            resources: res.len(),
            ..Self::default()
        };
        let mut wait = vec![0u32; tasks.len()];
        for t in tasks {
            s.dependencies += t.unlocks.len();
            s.locks += t.locks.len();
            s.uses += t.uses.len();
            s.payload_bytes += t.data.len();
            for u in &t.unlocks {
                wait[u.idx()] += 1;
            }
        }
        s.roots = wait.iter().filter(|&&w| w == 0).count();
        s.sinks = tasks.iter().filter(|t| t.unlocks.is_empty()).count();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::OWNER_NONE;
    use crate::coordinator::task::TaskId;
    use crate::coordinator::SchedConfig;

    #[test]
    fn scheduler_implements_builder() {
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let r = s.add_resource(None, 0);
        let t0 = s.task(0u32).lock(r).spawn();
        let t1 = s.task(1u32).cost(2).use_res(r).after([t0]).spawn();
        assert_eq!(s.nr_tasks_built(), 2);
        assert_eq!(s.nr_resources_built(), 1);
        assert_eq!(GraphBuilder::nr_queues(&s), 2);
        s.prepare().unwrap();
        assert_eq!(s.stats().tasks, 2);
        assert_eq!(s.stats().dependencies, 1);
        let _ = t1;
    }

    #[test]
    fn deprecated_shim_still_builds() {
        // The compat path must keep producing byte-identical graphs.
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        #[allow(deprecated)]
        let t = GraphBuilder::add_task(&mut s, 4, &7i32.to_le_bytes(), 3);
        s.prepare().unwrap();
        let v = s.task_view(t);
        assert_eq!(v.type_id, 4);
        assert_eq!(v.data, 7i32.to_le_bytes().as_slice());
        assert_eq!(v.cost, 3);
    }

    #[test]
    fn dyn_builder_raw_path_usable() {
        // The trait stays object-safe for the raw (non-generic) methods.
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let b: &mut dyn GraphBuilder = &mut s;
        let r = b.add_resource(None, 0);
        let t0 = b.raw_task(0, TaskFlags::default(), Vec::new(), 1);
        let t1 = b.raw_task(1, TaskFlags::default(), Vec::new(), 2);
        b.add_lock(t0, r);
        b.add_use(t1, r);
        b.add_unlock(t0, t1);
        assert_eq!(b.nr_queues(), 2);
        s.prepare().unwrap();
        assert_eq!(s.stats().tasks, 2);
        assert_eq!(s.stats().dependencies, 1);
    }

    fn build_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| Task::new(i as u32, TaskFlags::default(), vec![i as u8; i], 1 + i as i64))
            .collect()
    }

    #[test]
    fn freeze_flattens_into_arenas() {
        let mut res = ResTable::new();
        let r0 = res.add(None, OWNER_NONE);
        let r1 = res.add(None, OWNER_NONE);
        let mut ts = build_tasks(3);
        ts[0].add_unlock(TaskId(1));
        ts[0].add_unlock(TaskId(2));
        ts[1].add_unlock(TaskId(2));
        ts[0].add_lock(r1);
        ts[0].add_lock(r0);
        ts[0].add_lock(r1); // duplicate: deduped at freeze
        ts[1].add_use(r0);
        let g = CompiledGraph::freeze(&ts, &res).unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.unlock_ids(0), &[1, 2]);
        assert_eq!(g.lock_ids(0), &[0, 1], "locks come back sorted + deduped");
        assert_eq!(g.use_ids(1), &[0]);
        assert_eq!(g.data(2), &[2, 2]);
        assert_eq!(g.first_route(0), Some(r0));
        assert_eq!(g.first_route(1), Some(r0), "falls back to first use");
        assert_eq!(g.first_route(2), None);
        assert_eq!((g.wait0(0), g.wait0(1), g.wait0(2)), (0, 1, 2));
        assert_eq!(g.roots(), &[0]);
        // weights: cost 1,2,3 along the chain 0→{1,2},1→2.
        assert_eq!((g.weight(2), g.weight(1), g.weight(0)), (3, 5, 6));
        assert!(g.meta().arena_bytes() > 0);
    }

    #[test]
    fn freeze_subsumes_descendant_locks() {
        let mut res = ResTable::new();
        let root = res.add(None, OWNER_NONE);
        let mid = res.add(Some(root), OWNER_NONE);
        let leaf = res.add(Some(mid), OWNER_NONE);
        let other = res.add(None, OWNER_NONE);
        let mut ts = build_tasks(1);
        ts[0].add_lock(leaf);
        ts[0].add_lock(root);
        ts[0].add_lock(other);
        let g = CompiledGraph::freeze(&ts, &res).unwrap();
        assert_eq!(g.lock_ids(0), &[root.0, other.0]);
    }

    #[test]
    fn freeze_rejects_bad_handles() {
        let res = ResTable::new();
        let mut ts = build_tasks(1);
        ts[0].add_unlock(TaskId(5));
        assert!(matches!(
            CompiledGraph::freeze(&ts, &res),
            Err(SchedError::BadTask(5, 1))
        ));
        let mut ts = build_tasks(1);
        ts[0].add_unlock(TaskId(0));
        assert!(matches!(
            CompiledGraph::freeze(&ts, &res),
            Err(SchedError::SelfDependency(0))
        ));
        let mut ts = build_tasks(1);
        ts[0].add_lock(ResId(0));
        assert!(matches!(
            CompiledGraph::freeze(&ts, &res),
            Err(SchedError::BadRes(0, 0))
        ));
    }

    #[test]
    fn freeze_rejects_cycles() {
        let res = ResTable::new();
        let mut ts = build_tasks(2);
        ts[0].add_unlock(TaskId(1));
        ts[1].add_unlock(TaskId(0));
        assert!(matches!(
            CompiledGraph::freeze(&ts, &res),
            Err(SchedError::Cycle { .. })
        ));
    }

    #[test]
    fn freeze_ok_on_empty() {
        let g = CompiledGraph::freeze(&[], &ResTable::new()).unwrap();
        assert!(g.is_empty());
        assert!(g.roots().is_empty());
    }

    #[test]
    fn thaw_roundtrips() {
        let mut res = ResTable::new();
        let r0 = res.add(None, OWNER_NONE);
        let mut ts = build_tasks(3);
        ts[0].add_unlock(TaskId(2));
        ts[1].add_lock(r0);
        ts[2].add_use(r0);
        let g = CompiledGraph::freeze(&ts, &res).unwrap();
        let back = g.thaw();
        assert_eq!(back.len(), 3);
        for (a, b) in ts.iter().zip(&back) {
            assert_eq!(a.type_id, b.type_id);
            assert_eq!(a.data, b.data);
            assert_eq!(a.unlocks, b.unlocks);
            assert_eq!(a.locks, b.locks);
            assert_eq!(a.uses, b.uses);
            assert_eq!(a.cost, b.cost);
        }
        // Re-freezing the thawed records reproduces the same structure.
        let g2 = CompiledGraph::freeze(&back, &res).unwrap();
        assert_eq!(**g.meta(), **g2.meta());
    }

    #[test]
    fn adopt_meta_shares_identical_structure() {
        let res = ResTable::new();
        let ts = build_tasks(4);
        let a = CompiledGraph::freeze(&ts, &res).unwrap();
        let mut b = CompiledGraph::freeze(&ts, &res).unwrap();
        assert!(!Arc::ptr_eq(a.meta(), b.meta()));
        assert!(b.adopt_meta(a.meta()));
        assert!(Arc::ptr_eq(a.meta(), b.meta()));
        // A different graph refuses.
        let mut ts2 = build_tasks(4);
        ts2[0].add_unlock(TaskId(1));
        let mut c = CompiledGraph::freeze(&ts2, &res).unwrap();
        assert!(!c.adopt_meta(a.meta()));
    }

    #[test]
    fn build_stats_count() {
        let mut res = ResTable::new();
        let r0 = res.add(None, OWNER_NONE);
        let mut ts = build_tasks(3);
        ts[0].add_unlock(TaskId(1));
        ts[0].add_lock(r0);
        ts[1].add_use(r0);
        let st = GraphStats::of(&ts, &res);
        assert_eq!(st.tasks, 3);
        assert_eq!(st.dependencies, 1);
        assert_eq!(st.locks, 1);
        assert_eq!(st.uses, 1);
        assert_eq!(st.roots, 2);
        assert_eq!(st.sinks, 2);
        assert_eq!(st.payload_bytes, 3);
    }
}
