//! Graph-construction abstraction: the applications (QR, Cholesky,
//! Barnes-Hut) emit their task graphs through this trait, so the same
//! generator can target the real [`Scheduler`] or the dependency-only
//! baseline ([`crate::baselines::DepOnlyBuilder`]) for the Fig. 8/11
//! comparisons.
//!
//! Graphs are built through the typed [`TaskSpec`] entry point
//! ([`GraphBuilder::task`]); the untyped byte-payload
//! [`GraphBuilder::add_task`] remains as a deprecated shim.

use super::resource::ResId;
use super::scheduler::{ResHandle, Scheduler, TaskHandle};
use super::spec::TaskSpec;
use super::task::{TaskFlags, TaskType};

pub trait GraphBuilder {
    /// Emit one task with explicit flags and owned payload bytes — the
    /// primitive [`TaskSpec::spawn`] lowers to. Application code should
    /// use [`GraphBuilder::task`] instead.
    fn raw_task(&mut self, type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> TaskHandle;

    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle;
    fn add_lock(&mut self, t: TaskHandle, r: ResId);
    fn add_use(&mut self, t: TaskHandle, r: ResId);
    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle);
    fn nr_queues(&self) -> usize;

    /// Tasks emitted so far (spec validation of `after` handles).
    fn nr_tasks_built(&self) -> usize;

    /// Resources emitted so far (spec validation of `lock`/`use`
    /// handles).
    fn nr_resources_built(&self) -> usize;

    /// Start a typed [`TaskSpec`] for a task of type `ty`:
    /// `b.task(QrTask::Geqrf).payload(&(i, j, k)).cost(c).lock(r).spawn()`.
    fn task<T: TaskType>(&mut self, ty: T) -> TaskSpec<'_, Self>
    where
        Self: Sized,
    {
        TaskSpec::new(self, ty.type_id())
    }

    /// The legacy untyped build call (`qsched_addtask` with pre-packed
    /// payload bytes), kept so out-of-tree callers and the
    /// paper-fidelity tests compile unchanged.
    #[deprecated(
        since = "0.3.0",
        note = "build through the typed TaskSpec API: `b.task(ty).payload(&…).cost(c).spawn()`"
    )]
    fn add_task(&mut self, type_id: u32, data: &[u8], cost: i64) -> TaskHandle {
        self.raw_task(type_id, TaskFlags::default(), data.to_vec(), cost)
    }
}

impl GraphBuilder for Scheduler {
    fn raw_task(&mut self, type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> TaskHandle {
        self.push_task(type_id, flags, data, cost)
    }

    fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle {
        Scheduler::add_resource(self, parent, owner)
    }

    fn add_lock(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_lock(self, t, r)
    }

    fn add_use(&mut self, t: TaskHandle, r: ResId) {
        Scheduler::add_use(self, t, r)
    }

    fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        Scheduler::add_unlock(self, ta, tb)
    }

    fn nr_queues(&self) -> usize {
        Scheduler::nr_queues(self)
    }

    fn nr_tasks_built(&self) -> usize {
        self.nr_tasks()
    }

    fn nr_resources_built(&self) -> usize {
        self.nr_resources()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SchedConfig;

    #[test]
    fn scheduler_implements_builder() {
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let r = s.add_resource(None, 0);
        let t0 = s.task(0u32).lock(r).spawn();
        let t1 = s.task(1u32).cost(2).use_res(r).after([t0]).spawn();
        assert_eq!(s.nr_tasks_built(), 2);
        assert_eq!(s.nr_resources_built(), 1);
        assert_eq!(GraphBuilder::nr_queues(&s), 2);
        s.prepare().unwrap();
        assert_eq!(s.stats().tasks, 2);
        assert_eq!(s.stats().dependencies, 1);
        let _ = t1;
    }

    #[test]
    fn deprecated_shim_still_builds() {
        // The compat path must keep producing byte-identical graphs.
        let mut s = Scheduler::new(SchedConfig::new(1)).unwrap();
        #[allow(deprecated)]
        let t = GraphBuilder::add_task(&mut s, 4, &7i32.to_le_bytes(), 3);
        s.prepare().unwrap();
        let v = s.task_view(t);
        assert_eq!(v.type_id, 4);
        assert_eq!(v.data, 7i32.to_le_bytes().as_slice());
        assert_eq!(v.cost, 3);
    }

    #[test]
    fn dyn_builder_raw_path_usable() {
        // The trait stays object-safe for the raw (non-generic) methods.
        let mut s = Scheduler::new(SchedConfig::new(2)).unwrap();
        let b: &mut dyn GraphBuilder = &mut s;
        let r = b.add_resource(None, 0);
        let t0 = b.raw_task(0, TaskFlags::default(), Vec::new(), 1);
        let t1 = b.raw_task(1, TaskFlags::default(), Vec::new(), 2);
        b.add_lock(t0, r);
        b.add_use(t1, r);
        b.add_unlock(t0, t1);
        assert_eq!(b.nr_queues(), 2);
        s.prepare().unwrap();
        assert_eq!(s.stats().tasks, 2);
        assert_eq!(s.stats().dependencies, 1);
    }
}
