//! The frozen CSR/SoA task graph — what the scheduler actually runs.
//!
//! The builder-side [`Task`](super::task::Task) record is a faithful C
//! transliteration: four separately heap-allocated `Vec`s per task
//! (payload, unlocks, locks, uses) with the hot per-run atomics
//! interleaved between cold build-time metadata. That layout chases one
//! pointer per adjacency list on every `gettask`/`complete`, and a
//! completion-path `fetch_sub` on one task's wait counter drags its
//! neighbors' metadata through the coherence protocol.
//!
//! [`Scheduler::prepare`](super::Scheduler::prepare) therefore
//! *freezes* the builder's `Vec<Task>` into a [`CompiledGraph`]
//! (see `builder.rs` for the freeze itself — the only place the
//! per-task `Vec`s are still walked):
//!
//! * **One `u32` adjacency arena** (`FrozenGraph::adj`): every task's
//!   `unlocks ++ locks ++ uses` lists laid out back to back, addressed
//!   by per-task [`Span`]s. `Queue::get`'s conflict scan and
//!   `complete`'s dependent walk read consecutive words of one
//!   allocation instead of chasing per-task pointers — the PTG/CSR
//!   flattening StarPU- and PaRSEC-style runtimes use to keep
//!   `gettask` cache-resident.
//! * **One payload byte arena** (`FrozenGraph::payload`): all task
//!   data concatenated, `TaskView.data` borrowing a span of it.
//! * **SoA scalars**: `type_id`, virtual flags, precomputed initial
//!   wait counts ([`CompiledGraph::wait0`]) and the root list, so
//!   `start()` is `n` plain stores instead of an `O(edges)` atomic
//!   re-count.
//! * **Padded per-run state** ([`TaskRunState`]): the only words
//!   mutated during a parallel run (`wait`, `measured_ns`,
//!   `learned_ns`) live in a dedicated array, one 64-byte line per
//!   task, so a completion on task *i* cannot false-share with task
//!   *i±1*'s counters.
//!
//! The [`FrozenGraph`] half is immutable after the freeze and sits
//! behind an `Arc`: the server's template registry points every pooled
//! instance of one template at a single canonical copy
//! (`Scheduler::adopt_frozen_meta`), so read-only graph memory is
//! O(graph), not O(instances × graph). Costs and weights stay
//! per-instance (`relearn_costs` mutates them), as does the run-state
//! array.

use std::sync::atomic::{AtomicI32, AtomicI64, Ordering};
use std::sync::Arc;

use super::resource::ResId;
use super::task::{TaskId, TaskView};

/// A `(offset, len)` window into one of the frozen arenas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    pub off: u32,
    pub len: u32,
}

impl Span {
    #[inline]
    pub fn range(self) -> std::ops::Range<usize> {
        self.off as usize..(self.off + self.len) as usize
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.len == 0
    }
}

/// The immutable-after-freeze half of a compiled graph: arenas, spans,
/// and everything derived purely from the graph's *structure*. Shared
/// via `Arc` across template instances (content-compared by
/// [`Scheduler::adopt_frozen_meta`](super::Scheduler::adopt_frozen_meta)).
#[derive(Debug, PartialEq)]
pub struct FrozenGraph {
    pub(crate) n: usize,
    /// The adjacency arena: per task, `unlocks ++ locks ++ uses`
    /// contiguously. Unlock entries are task indices; lock/use entries
    /// are resource indices (see the span accessors on
    /// [`CompiledGraph`]).
    pub(crate) adj: Vec<u32>,
    /// The payload byte arena: all task data concatenated.
    pub(crate) payload: Vec<u8>,
    pub(crate) unlocks: Vec<Span>,
    pub(crate) locks: Vec<Span>,
    pub(crate) uses: Vec<Span>,
    pub(crate) data: Vec<Span>,
    pub(crate) type_id: Vec<u32>,
    pub(crate) virtual_flag: Vec<bool>,
    /// Initial dependency count per task (in-degree), precomputed at
    /// freeze so `start()` is a plain store per task.
    pub(crate) wait0: Vec<i32>,
    /// Tasks with `wait0 == 0`, in index order.
    pub(crate) roots: Vec<u32>,
}

impl FrozenGraph {
    /// Number of tasks.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Total adjacency-arena bytes + payload bytes (memory reporting).
    pub fn arena_bytes(&self) -> usize {
        self.adj.len() * std::mem::size_of::<u32>() + self.payload.len()
    }
}

/// Per-task mutable run state, one cache line per task (the 20 payload
/// bytes are padded to 64 by the alignment) so the completion-path
/// `fetch_sub` on one task's `wait` never false-shares with a
/// neighbor's.
#[derive(Debug)]
#[repr(align(64))]
pub struct TaskRunState {
    /// Number of unresolved dependencies; decremented by `qsched_done`.
    pub wait: AtomicI32,
    /// Measured execution time (ns) of the last run, for cost
    /// relearning.
    pub measured_ns: AtomicI64,
    /// Measured time carried across `reset_run` cycles (snapshotted
    /// from `measured_ns` before zeroing, so template reuse does not
    /// discard timings before `relearn_costs` consumes them).
    pub learned_ns: AtomicI64,
}

impl TaskRunState {
    pub fn new() -> Self {
        Self {
            wait: AtomicI32::new(0),
            measured_ns: AtomicI64::new(0),
            learned_ns: AtomicI64::new(0),
        }
    }

    /// Decrement the wait counter, returning the *new* value. The
    /// caller (scheduler `complete`) enqueues the task when this hits
    /// zero.
    #[inline]
    pub fn dec_wait(&self) -> i32 {
        self.wait.fetch_sub(1, Ordering::AcqRel) - 1
    }

    /// Current wait count.
    #[inline]
    pub fn wait_count(&self) -> i32 {
        self.wait.load(Ordering::Acquire)
    }
}

impl Default for TaskRunState {
    fn default() -> Self {
        Self::new()
    }
}

/// A compiled task graph: the shared frozen structure plus this
/// instance's costs, weights, and padded per-run state. Produced by
/// `CompiledGraph::freeze` (in `builder.rs`) from the builder's
/// `Vec<Task>`; owned by the [`Scheduler`](super::Scheduler) after
/// `prepare()`.
pub struct CompiledGraph {
    /// Frozen structure, shareable across instances of one template.
    pub(crate) meta: Arc<FrozenGraph>,
    /// Per-instance cost (user estimate, overwritten by
    /// `relearn_costs`).
    pub(crate) cost: Vec<i64>,
    /// Per-instance critical-path weight.
    pub(crate) weight: Vec<i64>,
    /// Per-instance, cache-line-padded run state.
    pub(crate) run: Box<[TaskRunState]>,
}

impl CompiledGraph {
    /// Number of tasks.
    #[inline]
    pub fn len(&self) -> usize {
        self.meta.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.meta.n == 0
    }

    /// The shared frozen half.
    #[inline]
    pub fn meta(&self) -> &Arc<FrozenGraph> {
        &self.meta
    }

    #[inline]
    pub fn type_id(&self, i: usize) -> u32 {
        self.meta.type_id[i]
    }

    #[inline]
    pub fn is_virtual(&self, i: usize) -> bool {
        self.meta.virtual_flag[i]
    }

    #[inline]
    pub fn cost(&self, i: usize) -> i64 {
        self.cost[i]
    }

    #[inline]
    pub fn weight(&self, i: usize) -> i64 {
        self.weight[i]
    }

    /// Task indices this task unlocks (dependents), as raw `u32`s into
    /// the task table.
    #[inline]
    pub fn unlock_ids(&self, i: usize) -> &[u32] {
        &self.meta.adj[self.meta.unlocks[i].range()]
    }

    /// Resource indices this task must lock, id-sorted at freeze (the
    /// §3.3 dining-philosophers discipline), as raw `u32`s into the
    /// resource table.
    #[inline]
    pub fn lock_ids(&self, i: usize) -> &[u32] {
        &self.meta.adj[self.meta.locks[i].range()]
    }

    /// Resource indices this task uses (affinity hints only).
    #[inline]
    pub fn use_ids(&self, i: usize) -> &[u32] {
        &self.meta.adj[self.meta.uses[i].range()]
    }

    /// The task's payload bytes.
    #[inline]
    pub fn data(&self, i: usize) -> &[u8] {
        &self.meta.payload[self.meta.data[i].range()]
    }

    /// First locked (else first used) resource — the affinity/routing
    /// signal of `enqueue` and the shard layer.
    #[inline]
    pub fn first_route(&self, i: usize) -> Option<ResId> {
        self.lock_ids(i)
            .first()
            .or_else(|| self.use_ids(i).first())
            .map(|&r| ResId(r))
    }

    /// Initial dependency count of task `i`.
    #[inline]
    pub fn wait0(&self, i: usize) -> i32 {
        self.meta.wait0[i]
    }

    /// Tasks with no dependencies, in index order.
    #[inline]
    pub fn roots(&self) -> &[u32] {
        &self.meta.roots
    }

    /// The padded per-run state of task `i`.
    #[inline]
    pub fn run(&self, i: usize) -> &TaskRunState {
        &self.run[i]
    }

    /// Read-only execution view of task `i` (what kernels receive).
    #[inline]
    pub fn view(&self, tid: TaskId) -> TaskView<'_> {
        let i = tid.idx();
        TaskView {
            tid,
            type_id: self.type_id(i),
            data: self.data(i),
            cost: self.cost(i),
            weight: self.weight(i),
        }
    }

    /// Point this instance at `canon`'s frozen structure if the two are
    /// structurally identical, dropping this instance's duplicate
    /// arenas. Returns whether the adoption happened. Used by the
    /// server's template registry so every pooled instance of one
    /// deterministic template shares a single read-only copy.
    pub fn adopt_meta(&mut self, canon: &Arc<FrozenGraph>) -> bool {
        if Arc::ptr_eq(&self.meta, canon) {
            return true;
        }
        if *self.meta == **canon {
            self.meta = Arc::clone(canon);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ranges() {
        let s = Span { off: 4, len: 3 };
        assert_eq!(s.range(), 4..7);
        assert!(!s.is_empty());
        assert!(Span::default().is_empty());
    }

    #[test]
    fn run_state_is_padded_and_counts() {
        assert_eq!(std::mem::size_of::<TaskRunState>(), 64);
        assert_eq!(std::mem::align_of::<TaskRunState>(), 64);
        let r = TaskRunState::new();
        r.wait.store(2, Ordering::Release);
        assert_eq!(r.dec_wait(), 1);
        assert_eq!(r.dec_wait(), 0);
        assert_eq!(r.wait_count(), 0);
    }
}
