//! The scheduler object (paper §3.4): owns tasks, resources, and queues;
//! manages dependencies; routes ready tasks to queues by resource
//! affinity; serves `gettask` with random-order work stealing; and
//! processes completions (`done`), unlocking resources and dependents.
//!
//! Lifecycle: build (`add_*` into the builder-side `Vec<Task>`) →
//! [`Scheduler::prepare`] (validate + *freeze* the graph into the
//! CSR/SoA [`CompiledGraph`]: one shared adjacency arena, one payload
//! arena, padded per-run atomics, sorted lock sets, precomputed wait
//! counts, critical-path weights) → run via
//! [`Scheduler::run`](super::exec) or the virtual-time executor
//! ([`super::sim`]), each of which calls [`Scheduler::start`] internally.
//! Every hot path below `prepare()` reads the compiled spans; resuming
//! *building* after a `prepare()` transparently thaws the compiled graph
//! back into builder records.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use super::compiled::{CompiledGraph, FrozenGraph};
use super::config::{ExecMode, SchedConfig, StealPolicy};
use super::error::{Result, SchedError};
use super::graph::GraphStats;
use super::queue::Queue;
use super::resource::{ResId, ResTable};
use super::task::{Task, TaskFlags, TaskId, TaskView};
use super::weights::{compute_weights, critical_path, total_work};
use crate::util::pad::CachePadded;
use crate::util::rng::Rng;

/// Public alias for task handles (the paper's `qsched_task_t`).
pub type TaskHandle = TaskId;
/// Public alias for resource handles (the paper's `qsched_res_t`).
pub type ResHandle = ResId;

/// Receiver for ready-task announcements when the scheduler's internal
/// per-queue routing is bypassed.
///
/// Installed via [`Scheduler::set_ready_sink`], the sink intercepts
/// every task that would otherwise be routed to one of the scheduler's
/// own queues by `enqueue` (from `start()` roots and from dependency
/// resolution in [`Scheduler::complete`]). The server's shared sharded
/// dispatch layer (`server::shard`) is the intended consumer: it tags
/// the task with its job and places it in a cross-job
/// [`super::queue::TaggedQueue`] shard, where workers later claim it
/// through [`Scheduler::try_acquire`] instead of
/// [`Scheduler::gettask`].
///
/// `route` is the task's first locked resource (falling back to its
/// first used resource) — the affinity signal the shard layer hashes
/// into a shard index, standing in for the paper's owner-queue routing.
///
/// Implementations must be cheap and non-blocking: `ready` is called on
/// the completion hot path, potentially from many workers at once.
pub trait ReadySink: Send + Sync {
    fn ready(&self, tid: TaskId, key: i64, route: Option<ResId>);
}

/// Always-on acquisition counters (cache-line-padded, relaxed bumps):
/// the scheduler-level slice of the crate's observability layer. Every
/// `gettask` call/hit/steal and every `try_acquire` attempt/failure is
/// counted here when `SchedFlags::obs_counters` is set (the default),
/// cumulatively over the scheduler's lifetime — `reset_run` does not
/// rewind them, mirroring `QueueStats`.
#[derive(Debug, Default)]
pub(crate) struct SchedObs {
    pub(crate) gettask_calls: CachePadded<AtomicU64>,
    pub(crate) gettask_hits: CachePadded<AtomicU64>,
    pub(crate) gettask_steals: CachePadded<AtomicU64>,
    pub(crate) acquire_attempts: CachePadded<AtomicU64>,
    pub(crate) acquire_failures: CachePadded<AtomicU64>,
}

/// The task scheduler (paper §3.4 `struct qsched`).
pub struct Scheduler {
    /// Builder-side task records; drained into `compiled` by
    /// [`Scheduler::prepare`] and reconstituted (thawed) only if the
    /// caller resumes building afterwards.
    pub(crate) tasks: Vec<Task>,
    /// The frozen CSR/SoA graph every runtime path reads.
    pub(crate) compiled: Option<CompiledGraph>,
    pub(crate) res: ResTable,
    pub(crate) queues: Vec<Queue>,
    pub(crate) config: SchedConfig,
    /// Tasks not yet completed in the current run (`s->waiting`).
    pub(crate) waiting: AtomicI64,
    /// Tasks currently sitting in some queue (ready, not yet acquired).
    /// A cheap hint for executors to skip polling empty queues
    /// (§Perf opt D).
    pub(crate) queued: AtomicI64,
    prepared: bool,
    /// Condvar support for `ExecMode::Yield` (qsched_flag_yield).
    pub(crate) wait_lock: Mutex<()>,
    pub(crate) wait_cv: Condvar,
    /// When set, ready tasks bypass the internal queues and are handed
    /// to this sink instead (shared sharded dispatch; see [`ReadySink`]).
    ready_sink: RwLock<Option<Arc<dyn ReadySink>>>,
    /// Fast-path mirror of `ready_sink.is_some()`: `enqueue` checks this
    /// single atomic before ever touching the lock, so single-graph runs
    /// that never install a sink pay one relaxed load per enqueue, not
    /// an RwLock round-trip.
    has_sink: AtomicBool,
    /// Always-on acquisition counters (see [`Scheduler::obs_counters`]).
    obs: SchedObs,
}

impl Scheduler {
    /// `qsched_init`: create a scheduler with `config.nr_queues` queues.
    pub fn new(config: SchedConfig) -> Result<Self> {
        if config.nr_queues == 0 {
            return Err(SchedError::NoQueues(0));
        }
        let queues = (0..config.nr_queues).map(|_| Queue::new(64)).collect();
        Ok(Self {
            tasks: Vec::new(),
            compiled: None,
            res: ResTable::new(),
            queues,
            config,
            waiting: AtomicI64::new(0),
            queued: AtomicI64::new(0),
            prepared: false,
            wait_lock: Mutex::new(()),
            wait_cv: Condvar::new(),
            ready_sink: RwLock::new(None),
            has_sink: AtomicBool::new(false),
            obs: SchedObs::default(),
        })
    }

    /// Install (or clear) the [`ReadySink`] that receives ready tasks in
    /// place of the internal queues.
    ///
    /// Must be called while no run is in flight — the canonical sequence
    /// on the server is `reset_run()` → `set_ready_sink(Some(…))` →
    /// `start()`, and the sink is cleared again when the job finalizes
    /// (both [`Scheduler::reset_run`] and explicit
    /// `set_ready_sink(None)` clear it). Takes `&self`: the field is
    /// interior-mutable so an `Arc`-shared template instance can be
    /// rebound per job.
    pub fn set_ready_sink(&self, sink: Option<Arc<dyn ReadySink>>) {
        let installed = sink.is_some();
        if !installed {
            // Drop the fast-path flag first so concurrent enqueues stop
            // consulting the lock before the sink disappears.
            self.has_sink.store(false, Ordering::Release);
        }
        *self.ready_sink.write().unwrap() = sink;
        if installed {
            self.has_sink.store(true, Ordering::Release);
        }
    }

    /// `qsched_reset`: drop tasks and resources, keep queues/config.
    pub fn reset(&mut self) {
        self.tasks.clear();
        self.compiled = None;
        self.res = ResTable::new();
        for q in &self.queues {
            q.clear();
        }
        self.waiting.store(0, Ordering::Release);
        self.queued.store(0, Ordering::Release);
        self.prepared = false;
    }

    /// Rewind all *per-run* state while keeping the compiled graph and
    /// the work `prepare()` did (freeze, lock sorting, critical-path
    /// weights): clear the queues and every transient counter so the
    /// same prepared graph can be resubmitted. This is the
    /// template-reuse path of the server (`server::registry`): per-job
    /// cost becomes dependency-counter reinitialization over the padded
    /// run-state array instead of graph reconstruction + `prepare()` —
    /// the frozen arenas (adjacency + payload) are never touched.
    ///
    /// The previous run's measured task times are snapshotted into each
    /// task's `learned_ns` before `measured_ns` is zeroed, so
    /// [`Scheduler::relearn_costs`] can still consume them after any
    /// number of reset cycles (template reuse must not discard timings).
    ///
    /// Takes `&self`: every field touched is interior-mutable, so a
    /// shared (`Arc`-held) scheduler can be recycled between jobs.
    /// Must only be called while no run is in flight (the run either
    /// completed — all counters already quiescent — or was abandoned).
    pub fn reset_run(&self) -> Result<()> {
        if !self.prepared {
            return Err(SchedError::NotPrepared("call prepare() before reset_run()"));
        }
        for q in &self.queues {
            q.clear();
        }
        let g = self.compiled.as_ref().expect("prepared implies compiled");
        for run in g.run.iter() {
            run.wait.store(0, Ordering::Relaxed);
            let measured = run.measured_ns.swap(0, Ordering::Relaxed);
            if measured > 0 {
                run.learned_ns.store(measured, Ordering::Relaxed);
            }
        }
        self.waiting.store(0, Ordering::Release);
        self.queued.store(0, Ordering::Release);
        // A pooled instance must never carry the previous job's sink
        // into its next activation (the shard layer re-installs one per
        // job, tagged with the new job's slot).
        self.set_ready_sink(None);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Build API (single-threaded)
    // ------------------------------------------------------------------

    /// Reconstitute the builder records from the compiled graph so the
    /// caller can keep building after a `prepare()`. No-op while the
    /// graph is unfrozen.
    fn thaw(&mut self) {
        if let Some(g) = self.compiled.take() {
            debug_assert!(self.tasks.is_empty(), "frozen scheduler kept builder records");
            self.tasks = g.thaw();
        }
        self.prepared = false;
    }

    /// `qsched_addtask` with owned payload bytes — the primitive the
    /// typed [`super::spec::TaskSpec`] API lowers to.
    pub(crate) fn push_task(
        &mut self,
        type_id: u32,
        flags: TaskFlags,
        data: Vec<u8>,
        cost: i64,
    ) -> TaskHandle {
        self.thaw();
        let id = TaskId(self.tasks.len() as u32);
        self.tasks.push(Task::new(type_id, flags, data, cost));
        id
    }

    /// `qsched_addtask`: create a task, copying `data` in.
    ///
    /// Deprecated shim over the typed API — build through
    /// [`super::builder::GraphBuilder::task`] instead:
    /// `sched.task(ty).payload(&…).cost(c).spawn()`.
    #[deprecated(
        since = "0.3.0",
        note = "build through the typed TaskSpec API: `sched.task(ty).payload(&…).cost(c).spawn()`"
    )]
    pub fn add_task(&mut self, type_id: u32, flags: TaskFlags, data: &[u8], cost: i64) -> TaskHandle {
        self.push_task(type_id, flags, data.to_vec(), cost)
    }

    /// `qsched_addres`: create a resource, optionally under a parent and
    /// with an initial owner queue.
    pub fn add_resource(&mut self, parent: Option<ResHandle>, owner: i32) -> ResHandle {
        self.thaw();
        self.res.add(parent, owner)
    }

    /// `qsched_addlock`: task `t` must exclusively lock `r` to run.
    pub fn add_lock(&mut self, t: TaskHandle, r: ResHandle) {
        self.thaw();
        self.tasks[t.idx()].add_lock(r);
    }

    /// `qsched_adduse`: task `t` uses `r` (queue-affinity hint only).
    pub fn add_use(&mut self, t: TaskHandle, r: ResHandle) {
        self.thaw();
        self.tasks[t.idx()].add_use(r);
    }

    /// `qsched_addunlock(ta, tb)`: `tb` depends on `ta`.
    pub fn add_unlock(&mut self, ta: TaskHandle, tb: TaskHandle) {
        self.thaw();
        self.tasks[ta.idx()].add_unlock(tb);
    }

    pub fn nr_tasks(&self) -> usize {
        match &self.compiled {
            Some(g) => g.len(),
            None => self.tasks.len(),
        }
    }

    pub fn nr_resources(&self) -> usize {
        self.res.len()
    }

    pub fn nr_queues(&self) -> usize {
        self.queues.len()
    }

    pub fn config(&self) -> &SchedConfig {
        &self.config
    }

    pub fn stats(&self) -> GraphStats {
        match &self.compiled {
            Some(g) => GraphStats::of_compiled(g, &self.res),
            None => GraphStats::of(&self.tasks, &self.res),
        }
    }

    /// Critical-path length (max weight); valid after `prepare`.
    pub fn critical_path(&self) -> i64 {
        self.compiled.as_ref().map_or(0, critical_path)
    }

    /// Total serial work (sum of costs).
    pub fn total_work(&self) -> i64 {
        match &self.compiled {
            Some(g) => total_work(g),
            None => self.tasks.iter().map(|t| t.cost).sum(),
        }
    }

    pub fn task_view(&self, tid: TaskId) -> TaskView<'_> {
        match &self.compiled {
            Some(g) => g.view(tid),
            None => {
                let t = &self.tasks[tid.idx()];
                TaskView { tid, type_id: t.type_id, data: &t.data, cost: t.cost, weight: 0 }
            }
        }
    }

    /// `(type_id, is_virtual)` of a task, pre- or post-freeze
    /// ([`super::registry::KernelRegistry::validate`]).
    pub fn task_kind(&self, tid: TaskId) -> (u32, bool) {
        match &self.compiled {
            Some(g) => (g.type_id(tid.idx()), g.is_virtual(tid.idx())),
            None => {
                let t = &self.tasks[tid.idx()];
                (t.type_id, t.flags.virtual_task)
            }
        }
    }

    /// The locked resources of a task in the frozen (id-sorted,
    /// ancestor-subsumed) order; valid after `prepare`. Diagnostic.
    pub fn locks_of(&self, tid: TaskId) -> Vec<ResId> {
        match &self.compiled {
            Some(g) => g.lock_ids(tid.idx()).iter().map(|&r| ResId(r)).collect(),
            None => self.tasks[tid.idx()].locks.clone(),
        }
    }

    /// The compiled (frozen) graph, once `prepare()` has run. Benches
    /// and diagnostics use this to reach the span accessors directly.
    pub fn compiled_graph(&self) -> Option<&CompiledGraph> {
        self.compiled.as_ref()
    }

    /// The shared frozen half of the compiled graph (arenas + spans),
    /// once `prepare()` has run.
    pub fn frozen_meta(&self) -> Option<&Arc<FrozenGraph>> {
        self.compiled.as_ref().map(|g| g.meta())
    }

    /// Point this instance's compiled graph at `canon`'s frozen
    /// structure if the two are structurally identical, dropping the
    /// duplicate arenas (see [`CompiledGraph::adopt_meta`]). The server
    /// registry calls this after each template build so all pooled
    /// instances of one deterministic template share a single read-only
    /// copy. Returns whether the adoption happened.
    pub fn adopt_frozen_meta(&mut self, canon: &Arc<FrozenGraph>) -> bool {
        match &mut self.compiled {
            Some(g) => g.adopt_meta(canon),
            None => false,
        }
    }

    pub fn resources(&self) -> &ResTable {
        &self.res
    }

    /// Freeze the graph: validate handles, sort + dedup + subsume each
    /// task's lock set (the §3.3 dining-philosophers fix), flatten all
    /// adjacency lists and payloads into the shared arenas, precompute
    /// wait counts and roots, and compute critical-path weights (cycle
    /// check). See [`CompiledGraph`] for the layout. Idempotent; on
    /// error the builder records are left untouched.
    pub fn prepare(&mut self) -> Result<()> {
        if self.prepared && self.compiled.is_some() {
            return Ok(());
        }
        let g = CompiledGraph::freeze(&self.tasks, &self.res)?;
        self.compiled = Some(g);
        // The builder records are fully represented by the compiled
        // graph now (thaw reconstitutes them on demand).
        self.tasks = Vec::new();
        self.prepared = true;
        Ok(())
    }

    /// `qsched_start`: reset wait counters and the waiting count, clear
    /// the queues, and enqueue every task with no unresolved
    /// dependencies. The initial counts were precomputed at freeze
    /// ([`CompiledGraph::wait0`]), so this is one plain store per task —
    /// no per-edge atomic re-count. Virtual ready tasks complete
    /// immediately (they have no action).
    ///
    /// Public for callers driving the scheduler manually
    /// (`start`/`gettask`/`complete` loops — the stress tests and the
    /// server's virtual twins); `run`/`run_sim` call it internally.
    pub fn start(&self) -> Result<()> {
        if !self.prepared {
            return Err(SchedError::NotPrepared("call prepare() before running"));
        }
        let g = self.compiled.as_ref().expect("prepared implies compiled");
        for q in &self.queues {
            q.clear();
        }
        for i in 0..g.len() {
            g.run(i).wait.store(g.wait0(i), Ordering::Relaxed);
        }
        self.waiting.store(g.len() as i64, Ordering::Release);
        self.queued.store(0, Ordering::Release);
        for &r in g.roots() {
            if g.is_virtual(r as usize) {
                self.complete(TaskId(r));
            } else {
                self.enqueue(TaskId(r));
            }
        }
        Ok(())
    }

    /// Number of tasks not yet completed in the current run.
    #[inline]
    pub fn waiting(&self) -> i64 {
        self.waiting.load(Ordering::Acquire)
    }

    /// Number of ready tasks currently queued — a *hint* with the
    /// following exact consistency contract (identical whether tasks sit
    /// in the internal queues or in a shared shard via a [`ReadySink`]):
    ///
    /// * **Upper bound.** The hint never exceeds `ready + acquired`: the
    ///   number of entries currently sitting in a queue/shard plus the
    ///   number of tasks a worker has removed and resource-locked but
    ///   not yet decremented for. The increment happens only *after* an
    ///   entry is physically queued (`put`/`ready` first, `fetch_add`
    ///   second), so the counter can never get ahead of work that does
    ///   not exist. Equivalently: it never exceeds the number of
    ///   uncompleted tasks of the current run.
    /// * **Transient undercount.** Between an entry's insertion and its
    ///   `fetch_add` (and symmetrically between a removal and its
    ///   `fetch_sub` in [`Scheduler::gettask`] /
    ///   [`Scheduler::try_acquire`]) the hint may briefly undercount —
    ///   a reader may skip a probe that would have found work. Callers
    ///   therefore use it only to *skip* polling, never to conclude a
    ///   run is finished; drain detection always goes through
    ///   [`Scheduler::waiting`].
    /// * **Exact at quiescence.** Whenever no enqueue or acquisition is
    ///   in flight (before `start()`, after the last `complete()`, after
    ///   `reset_run()`), the hint equals the true queued count.
    ///
    /// The upper bound is asserted under concurrency by the
    /// `queued_hint_never_exceeds_ready_plus_acquired` stress test in
    /// `rust/tests/prop_scheduler.rs`.
    #[inline]
    pub fn queued_hint(&self) -> i64 {
        self.queued.load(Ordering::Acquire)
    }

    /// Scheduling key for a task: the critical-path weight by default
    /// (§3.1), optionally penalized by its conflict degree (§5
    /// "Priorities" extension) or replaced per [`KeyPolicy`] for the
    /// baseline/ablation configurations.
    ///
    /// [`KeyPolicy`]: super::config::KeyPolicy
    #[inline]
    fn key_of(&self, g: &CompiledGraph, tid: TaskId) -> i64 {
        let i = tid.idx();
        let base = match self.config.flags.key_policy {
            super::config::KeyPolicy::CriticalPath => g.weight(i),
            super::config::KeyPolicy::Fifo => -(tid.0 as i64),
            super::config::KeyPolicy::Cost => g.cost(i),
        };
        if self.config.flags.lock_aware_priority {
            base - g.cost(i) * g.lock_ids(i).len() as i64
        } else {
            base
        }
    }

    /// `qsched_enqueue`: route a ready task to the queue owning most of
    /// its resources (locks + uses); ties and no-owner default to queue 0,
    /// as in the paper. When a [`ReadySink`] is installed the task is
    /// announced to it instead (with its key and first lock/use resource
    /// as the routing hint) and the internal queues stay untouched.
    pub(crate) fn enqueue(&self, tid: TaskId) {
        let g = self.compiled.as_ref().expect("enqueue before prepare()");
        let i = tid.idx();
        debug_assert!(!g.is_virtual(i));
        if self.has_sink.load(Ordering::Acquire) {
            let sink = self.ready_sink.read().unwrap().clone();
            // A stale flag (sink cleared concurrently) falls through to
            // the internal queues.
            if let Some(sink) = sink {
                let key = self.key_of(g, tid);
                sink.ready(tid, key, g.first_route(i));
                self.queued.fetch_add(1, Ordering::AcqRel);
                if self.config.flags.mode == ExecMode::Yield {
                    let _g = self.wait_lock.lock().unwrap();
                    self.wait_cv.notify_all();
                }
                return;
            }
        }
        let nq = self.queues.len();
        let mut best = 0usize;
        if nq > 1 {
            // §Perf opt B: fixed-size score buffer — `enqueue` runs once
            // per task on the hot path, and a heap allocation per task
            // showed up in profiles. 64 queues covers the paper's
            // machine; larger configurations fall back to the heap.
            let mut stack_score = [0u32; 64];
            let mut heap_score;
            let score: &mut [u32] = if nq <= 64 {
                &mut stack_score[..nq]
            } else {
                heap_score = vec![0u32; nq];
                &mut heap_score
            };
            let mut best_score = 0u32;
            for &rid in g.lock_ids(i).iter().chain(g.use_ids(i).iter()) {
                let owner = self.res.get(ResId(rid)).owner();
                if owner >= 0 && (owner as usize) < nq {
                    let q = owner as usize;
                    score[q] += 1;
                    if score[q] > best_score {
                        best_score = score[q];
                        best = q;
                    }
                }
            }
        }
        self.queues[best].put(self.key_of(g, tid), tid);
        self.queued.fetch_add(1, Ordering::AcqRel);
        if self.config.flags.mode == ExecMode::Yield {
            let _g = self.wait_lock.lock().unwrap();
            self.wait_cv.notify_all();
        }
    }

    /// `qsched_gettask`: try the preferred queue, then steal from the
    /// others (random order by default; heaviest-first under the §5
    /// weight-aware ablation). On success the task's resources are locked;
    /// if re-owning is on, they are re-owned to `qid`.
    /// Returns `(task, was_stolen)`.
    ///
    /// The steal order is entirely the caller's `rng`: callers that
    /// want reproducible runs must derive it from a configured root
    /// seed (see `Rng::split`; both executors and the server pool do),
    /// never from entropy. This is what lets the simulator replay any
    /// steal schedule from one `u64`.
    pub fn gettask(&self, qid: usize, rng: &mut Rng) -> Option<(TaskId, bool)> {
        let g = self.compiled.as_ref().expect("gettask before prepare()");
        let obs = self.config.flags.obs_counters;
        if obs {
            self.obs.gettask_calls.fetch_add(1, Ordering::Relaxed);
        }
        let nq = self.queues.len();
        let mut got: Option<(TaskId, bool)> = None;
        if let Some(tid) = self.queues[qid].get(g, &self.res) {
            got = Some((tid, false));
        } else if nq > 1 {
            match self.config.flags.steal {
                StealPolicy::Random => {
                    // Random-order probe of the other queues (§3.4):
                    // a random cyclic permutation instead of allocating
                    // and shuffling a Vec per steal attempt.
                    for k in rng.coprime_walk(nq) {
                        if k != qid {
                            if let Some(tid) = self.queues[k].get(g, &self.res) {
                                got = Some((tid, true));
                                break;
                            }
                        }
                    }
                }
                StealPolicy::WeightAware => {
                    let mut order: Vec<usize> = (0..nq).filter(|&k| k != qid).collect();
                    order.sort_by_key(|&k| std::cmp::Reverse(self.queues[k].total_key()));
                    for k in order {
                        if let Some(tid) = self.queues[k].get(g, &self.res) {
                            got = Some((tid, true));
                            break;
                        }
                    }
                }
            }
        }
        if let Some((tid, stolen)) = got {
            if obs {
                self.obs.gettask_hits.fetch_add(1, Ordering::Relaxed);
                if stolen {
                    self.obs.gettask_steals.fetch_add(1, Ordering::Relaxed);
                }
            }
            self.queued.fetch_sub(1, Ordering::AcqRel);
            if self.config.flags.reown {
                let i = tid.idx();
                for &rid in g.lock_ids(i).iter().chain(g.use_ids(i).iter()) {
                    self.res.get(ResId(rid)).set_owner(qid as i32);
                }
            }
        }
        got
    }

    /// Try to lock every resource of `tid` — the acquisition half of the
    /// shared-shard dispatch path, pairing with a [`ReadySink`] delivery
    /// the way [`Scheduler::gettask`] pairs with the internal queues.
    ///
    /// Locks are attempted in the id-sorted order the freeze fixed (the
    /// §3.3 dining-philosophers discipline) and rolled back on the first
    /// failure. On success the task counts as acquired: the
    /// [`Scheduler::queued_hint`] is decremented exactly as `gettask`
    /// would, and the caller owes a matching [`Scheduler::complete`].
    ///
    /// Re-owning (`flags.reown`) is deliberately *not* applied here: the
    /// shard layer routes by a stateless `(job, resource)` hash, so
    /// mutating owner hints would only perturb the single-graph path.
    pub fn try_acquire(&self, tid: TaskId) -> bool {
        let g = self.compiled.as_ref().expect("try_acquire before prepare()");
        let obs = self.config.flags.obs_counters;
        if obs {
            self.obs.acquire_attempts.fetch_add(1, Ordering::Relaxed);
        }
        let locks = g.lock_ids(tid.idx());
        for (j, &rid) in locks.iter().enumerate() {
            if !self.res.try_lock(ResId(rid)) {
                for &r_prev in &locks[..j] {
                    self.res.unlock(ResId(r_prev));
                }
                if obs {
                    self.obs.acquire_failures.fetch_add(1, Ordering::Relaxed);
                }
                return false;
            }
        }
        self.queued.fetch_sub(1, Ordering::AcqRel);
        true
    }

    /// `qsched_done`: release the task's resource locks, decrement each
    /// dependent's wait counter, enqueue any that hit zero (virtual
    /// dependents complete in place, iteratively), and decrement the
    /// global waiting count. The dependent walk reads one contiguous
    /// span of the adjacency arena, and each `dec_wait` lands on the
    /// dependent's own padded cache line.
    pub fn complete(&self, tid: TaskId) {
        let g = self.compiled.as_ref().expect("complete before prepare()");
        let mut stack = vec![tid];
        while let Some(t) = stack.pop() {
            let i = t.idx();
            if !g.is_virtual(i) {
                for &rid in g.lock_ids(i) {
                    self.res.unlock(ResId(rid));
                }
            }
            for &u in g.unlock_ids(i) {
                if g.run(u as usize).dec_wait() == 0 {
                    if g.is_virtual(u as usize) {
                        stack.push(TaskId(u));
                    } else {
                        self.enqueue(TaskId(u));
                    }
                }
            }
            self.waiting.fetch_sub(1, Ordering::AcqRel);
        }
        if self.config.flags.mode == ExecMode::Yield {
            let _g = self.wait_lock.lock().unwrap();
            self.wait_cv.notify_all();
        }
    }

    /// Store a measured execution time for cost relearning (§3.1).
    pub(crate) fn record_measured(&self, tid: TaskId, ns: u64) {
        self.compiled
            .as_ref()
            .expect("record_measured before prepare()")
            .run(tid.idx())
            .measured_ns
            .store(ns as i64, Ordering::Relaxed);
    }

    /// Measured execution time (ns) of a task's most recent run, or 0.
    /// Diagnostic.
    pub fn measured_ns(&self, tid: TaskId) -> i64 {
        self.compiled
            .as_ref()
            .map_or(0, |g| g.run(tid.idx()).measured_ns.load(Ordering::Relaxed))
    }

    /// Fold measured times back into costs and recompute weights
    /// (`relearn_costs`; called between runs). Consumes the live
    /// `measured_ns` of the most recent run, falling back to the
    /// `learned_ns` snapshot a [`Scheduler::reset_run`] cycle preserved.
    /// Costs and weights are per-instance arrays: relearning on one
    /// template instance never disturbs another sharing the frozen
    /// arenas.
    pub fn relearn_costs(&mut self) -> Result<()> {
        let Some(g) = self.compiled.as_mut() else {
            // Unfrozen (still building): nothing has run since the last
            // thaw, and any earlier timings were snapshotted into the
            // builder records' `learned_ns`, which the next freeze
            // re-seeds — so there is nothing to fold here.
            return Ok(());
        };
        let mut any = false;
        for i in 0..g.meta.n {
            let m = g.run[i].measured_ns.load(Ordering::Relaxed);
            let m = if m > 0 { m } else { g.run[i].learned_ns.load(Ordering::Relaxed) };
            if m > 0 {
                g.cost[i] = m.max(1);
                any = true;
            }
        }
        if any {
            compute_weights(g)?;
        }
        Ok(())
    }

    /// Always-on acquisition counters: `(gettask calls, gettask hits,
    /// gettask steals, try_acquire attempts, try_acquire failures)`,
    /// cumulative over the scheduler's lifetime. Zeros when
    /// `SchedFlags::obs_counters` is off. Complements
    /// [`Scheduler::queue_stats`] (scan lengths, spin counts) — together
    /// they are the Fig. 13 `qsched_gettask` overhead decomposition the
    /// observability layer exports.
    pub fn obs_counters(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.obs.gettask_calls.load(Ordering::Relaxed),
            self.obs.gettask_hits.load(Ordering::Relaxed),
            self.obs.gettask_steals.load(Ordering::Relaxed),
            self.obs.acquire_attempts.load(Ordering::Relaxed),
            self.obs.acquire_failures.load(Ordering::Relaxed),
        )
    }

    /// Aggregated queue statistics (gets, misses, scanned, lock failures,
    /// mutex spins) across all queues — Fig. 13 overhead inputs.
    pub fn queue_stats(&self) -> (u64, u64, u64, u64, u64) {
        let mut acc = (0, 0, 0, 0, 0);
        for q in &self.queues {
            let s = q.stats.snapshot();
            acc.0 += s.0;
            acc.1 += s.1;
            acc.2 += s.2;
            acc.3 += s.3;
            acc.4 += s.4;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::builder::GraphBuilder;
    use crate::coordinator::resource::OWNER_NONE;

    fn sched(nq: usize) -> Scheduler {
        Scheduler::new(SchedConfig::new(nq)).unwrap()
    }

    #[test]
    fn rejects_zero_queues() {
        assert!(matches!(
            Scheduler::new(SchedConfig::new(0)),
            Err(SchedError::NoQueues(0))
        ));
    }

    #[test]
    fn build_and_prepare() {
        let mut s = sched(2);
        let r = s.add_resource(None, 0);
        let a = s.task(0).payload(&1i32).cost(10).spawn();
        let b = s.task(1).cost(5).spawn();
        s.add_lock(b, r);
        s.add_unlock(a, b);
        s.prepare().unwrap();
        assert_eq!(s.nr_tasks(), 2);
        assert_eq!(s.nr_resources(), 1);
        assert_eq!(s.task_view(a).weight, 15);
        assert_eq!(s.critical_path(), 15);
        assert_eq!(s.total_work(), 15);
        assert!(s.compiled_graph().is_some(), "prepare freezes the graph");
    }

    #[test]
    fn prepare_rejects_cycles() {
        let mut s = sched(1);
        let a = s.task(0).spawn();
        let b = s.task(0).spawn();
        s.add_unlock(a, b);
        s.add_unlock(b, a);
        assert!(matches!(s.prepare(), Err(SchedError::Cycle { .. })));
        // The builder records survive the failed freeze.
        assert_eq!(s.nr_tasks(), 2);
    }

    #[test]
    fn prepare_subsumes_descendant_locks() {
        // Locking a resource and its ancestor in one task must collapse
        // to the ancestor alone (else the task self-deadlocks).
        let mut s = sched(1);
        let root = s.add_resource(None, OWNER_NONE);
        let mid = s.add_resource(Some(root), OWNER_NONE);
        let leaf = s.add_resource(Some(mid), OWNER_NONE);
        let other = s.add_resource(None, OWNER_NONE);
        let t = s.task(0).spawn();
        s.add_lock(t, leaf);
        s.add_lock(t, root);
        s.add_lock(t, other);
        s.prepare().unwrap();
        assert_eq!(s.locks_of(t), vec![root, other]);
        // And the task actually runs.
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (tid, _) = s.gettask(0, &mut rng).unwrap();
        s.complete(tid);
        assert!(s.res.all_quiescent());
    }

    #[test]
    fn prepare_sorts_and_dedups_locks() {
        let mut s = sched(1);
        let r0 = s.add_resource(None, OWNER_NONE);
        let r1 = s.add_resource(None, OWNER_NONE);
        let t = s.task(0).spawn();
        s.add_lock(t, r1);
        s.add_lock(t, r0);
        s.add_lock(t, r1);
        s.prepare().unwrap();
        assert_eq!(s.locks_of(t), vec![r0, r1]);
    }

    #[test]
    fn build_after_prepare_thaws_and_refreezes() {
        // Resuming construction after a freeze must transparently thaw
        // the compiled graph back into builder records.
        let mut s = sched(1);
        let a = s.task(0).cost(2).spawn();
        s.prepare().unwrap();
        assert!(s.compiled_graph().is_some());
        let b = s.task(0).cost(3).after([a]).spawn();
        assert!(s.compiled_graph().is_none(), "mutation thawed the graph");
        assert_eq!(s.nr_tasks(), 2);
        s.prepare().unwrap();
        assert_eq!(s.task_view(a).weight, 5);
        assert_eq!(s.stats().dependencies, 1);
        let _ = b;
    }

    #[test]
    fn start_enqueues_roots_only() {
        let mut s = sched(1);
        let a = s.task(0).spawn();
        let b = s.task(0).spawn();
        s.add_unlock(a, b);
        s.prepare().unwrap();
        s.start().unwrap();
        assert_eq!(s.waiting(), 2);
        assert_eq!(s.queues[0].len(), 1);
        let mut rng = Rng::new(0);
        let (tid, stolen) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(tid, a);
        assert!(!stolen);
        // b not yet available.
        assert!(s.gettask(0, &mut rng).is_none());
        s.complete(a);
        let (tid, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(tid, b);
        s.complete(b);
        assert_eq!(s.waiting(), 0);
        assert!(s.res.all_quiescent());
    }

    #[test]
    fn run_without_prepare_fails() {
        let s = sched(1);
        assert!(matches!(s.start(), Err(SchedError::NotPrepared(_))));
    }

    #[test]
    fn enqueue_prefers_owning_queue() {
        let mut s = sched(3);
        let r_q2 = s.add_resource(None, 2);
        let r_q2b = s.add_resource(None, 2);
        let r_q1 = s.add_resource(None, 1);
        let t = s.task(0).spawn();
        s.add_lock(t, r_q2);
        s.add_use(t, r_q2b);
        s.add_use(t, r_q1);
        s.prepare().unwrap();
        s.start().unwrap();
        assert_eq!(s.queues[2].len(), 1, "two of three resources owned by q2");
        assert_eq!(s.queues[0].len(), 0);
        assert_eq!(s.queues[1].len(), 0);
    }

    #[test]
    fn gettask_steals_from_other_queue() {
        let mut s = sched(2);
        let r = s.add_resource(None, 1); // owned by queue 1
        let t = s.task(0).spawn();
        s.add_lock(t, r);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (tid, stolen) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(tid, t);
        assert!(stolen, "task was in queue 1, fetched from queue 0");
        // reown on: the resource now belongs to queue 0.
        assert_eq!(s.res.get(r).owner(), 0);
        s.complete(tid);
    }

    #[test]
    fn reown_disabled_keeps_owner() {
        let mut cfg = SchedConfig::new(2);
        cfg.flags.reown = false;
        let mut s = Scheduler::new(cfg).unwrap();
        let r = s.add_resource(None, 1);
        let t = s.task(0).spawn();
        s.add_lock(t, r);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (tid, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(s.res.get(r).owner(), 1, "reown off: owner unchanged");
        s.complete(tid);
    }

    #[test]
    fn virtual_tasks_complete_without_execution() {
        // a -> V -> b where V is virtual: completing a must make b
        // available without anyone "running" V.
        let mut s = sched(1);
        let a = s.task(0).spawn();
        let v = s.task(9).virtual_task().spawn();
        let b = s.task(0).spawn();
        s.add_unlock(a, v);
        s.add_unlock(v, b);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (tid, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(tid, a);
        s.complete(a);
        assert_eq!(s.waiting(), 1, "a and v completed");
        let (tid, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(tid, b);
        s.complete(b);
        assert_eq!(s.waiting(), 0);
    }

    #[test]
    fn virtual_root_completes_at_start() {
        let mut s = sched(1);
        let v = s.task(0).virtual_task().spawn();
        let b = s.task(0).spawn();
        s.add_unlock(v, b);
        s.prepare().unwrap();
        s.start().unwrap();
        assert_eq!(s.waiting(), 1);
        let mut rng = Rng::new(0);
        assert_eq!(s.gettask(0, &mut rng).unwrap().0, b);
        s.complete(b);
        assert_eq!(s.waiting(), 0);
    }

    #[test]
    fn conflicting_tasks_serialized_via_locks() {
        let mut s = sched(1);
        let r = s.add_resource(None, OWNER_NONE);
        let a = s.task(0).spawn();
        let b = s.task(0).spawn();
        s.add_lock(a, r);
        s.add_lock(b, r);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (first, _) = s.gettask(0, &mut rng).unwrap();
        // Second conflicting task cannot be acquired while first holds r.
        assert!(s.gettask(0, &mut rng).is_none());
        s.complete(first);
        let (second, _) = s.gettask(0, &mut rng).unwrap();
        assert_ne!(first, second);
        s.complete(second);
        assert!(s.res.all_quiescent());
    }

    #[test]
    fn hierarchical_conflict_blocks_parent_task() {
        let mut s = sched(1);
        let root = s.add_resource(None, OWNER_NONE);
        let child = s.add_resource(Some(root), OWNER_NONE);
        let t_child = s.task(0).spawn();
        let t_root = s.task(0).spawn();
        s.add_lock(t_child, child);
        s.add_lock(t_root, root);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (first, _) = s.gettask(0, &mut rng).unwrap();
        assert!(
            s.gettask(0, &mut rng).is_none(),
            "root/child locks must exclude each other"
        );
        s.complete(first);
        let (second, _) = s.gettask(0, &mut rng).unwrap();
        s.complete(second);
        assert!(s.res.all_quiescent());
    }

    #[test]
    fn reset_clears_everything() {
        let mut s = sched(2);
        s.add_resource(None, 0);
        s.task(0).spawn();
        s.prepare().unwrap();
        s.reset();
        assert_eq!(s.nr_tasks(), 0);
        assert_eq!(s.nr_resources(), 0);
        assert!(s.compiled_graph().is_none());
        assert!(matches!(s.start(), Err(SchedError::NotPrepared(_))));
    }

    #[test]
    fn reset_run_keeps_graph_and_prepare() {
        let mut s = sched(1);
        let r = s.add_resource(None, OWNER_NONE);
        let a = s.task(0).cost(2).spawn();
        let b = s.task(0).cost(3).spawn();
        s.add_unlock(a, b);
        s.add_lock(b, r);
        s.prepare().unwrap();
        let mut rng = Rng::new(0);
        for _ in 0..3 {
            s.start().unwrap();
            let (t1, _) = s.gettask(0, &mut rng).unwrap();
            assert_eq!(t1, a);
            s.complete(t1);
            let (t2, _) = s.gettask(0, &mut rng).unwrap();
            assert_eq!(t2, b);
            s.complete(t2);
            assert_eq!(s.waiting(), 0);
            assert!(s.res.all_quiescent());
            s.reset_run().unwrap();
            assert_eq!(s.nr_tasks(), 2, "graph survives reset_run");
            assert_eq!(s.task_view(a).weight, 5, "weights survive reset_run");
        }
    }

    #[test]
    fn reset_run_requires_prepare() {
        let s = sched(1);
        assert!(matches!(s.reset_run(), Err(SchedError::NotPrepared(_))));
    }

    #[test]
    fn relearn_costs_updates_weights() {
        let mut s = sched(1);
        let a = s.task(0).spawn();
        let b = s.task(0).spawn();
        s.add_unlock(a, b);
        s.prepare().unwrap();
        s.record_measured(a, 100);
        s.record_measured(b, 50);
        s.relearn_costs().unwrap();
        assert_eq!(s.task_view(a).cost, 100);
        assert_eq!(s.task_view(a).weight, 150);
    }

    #[test]
    fn measured_costs_survive_reset_run() {
        // Template-reuse regression: reset_run used to zero measured_ns
        // outright, discarding the run's timings before cost relearning
        // could consume them. They must survive via the learned snapshot.
        let mut s = sched(1);
        let a = s.task(0).spawn();
        let b = s.task(0).after([a]).spawn();
        s.prepare().unwrap();
        let mut rng = Rng::new(0);
        s.start().unwrap();
        let (t1, _) = s.gettask(0, &mut rng).unwrap();
        s.record_measured(t1, 400);
        s.complete(t1);
        let (t2, _) = s.gettask(0, &mut rng).unwrap();
        s.record_measured(t2, 700);
        s.complete(t2);
        // The reuse path rewinds before anyone relearns…
        s.reset_run().unwrap();
        assert_eq!(s.measured_ns(a), 0, "reset_run clears the live measurement");
        // …and relearning afterwards still sees the measured times.
        s.relearn_costs().unwrap();
        assert_eq!(s.task_view(a).cost, 400);
        assert_eq!(s.task_view(b).cost, 700);
        assert_eq!(s.task_view(a).weight, 1100);
        // A later run's fresh measurements take precedence over the
        // snapshot.
        s.start().unwrap();
        let (t1, _) = s.gettask(0, &mut rng).unwrap();
        s.record_measured(t1, 900);
        s.complete(t1);
        let (t2, _) = s.gettask(0, &mut rng).unwrap();
        s.complete(t2);
        s.relearn_costs().unwrap();
        assert_eq!(s.task_view(a).cost, 900);
        assert_eq!(s.task_view(b).cost, 700, "unmeasured task keeps learned cost");
    }

    #[test]
    fn measurements_survive_thaw_refreeze() {
        // Regression: a run's measured times must survive a
        // post-run build mutation (which thaws the compiled graph and
        // its run-state atomics) so a later relearn still sees them —
        // the old Task-atomic layout got this for free.
        let mut s = sched(1);
        let a = s.task(0).spawn();
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        let (t1, _) = s.gettask(0, &mut rng).unwrap();
        s.record_measured(t1, 500);
        s.complete(t1);
        // Mutate (thaw: compiled graph dropped), then re-freeze.
        let b = s.task(0).cost(3).after([a]).spawn();
        s.prepare().unwrap();
        s.relearn_costs().unwrap();
        assert_eq!(s.task_view(a).cost, 500, "timing survived the thaw");
        assert_eq!(s.task_view(b).cost, 3, "new task keeps its estimate");
        assert_eq!(s.task_view(a).weight, 503);
    }

    #[test]
    fn ready_sink_redirects_and_try_acquire_pairs() {
        struct Collect(Mutex<Vec<(TaskId, i64, Option<ResId>)>>);
        impl ReadySink for Collect {
            fn ready(&self, tid: TaskId, key: i64, route: Option<ResId>) {
                self.0.lock().unwrap().push((tid, key, route));
            }
        }
        let mut s = sched(2);
        let r = s.add_resource(None, OWNER_NONE);
        let a = s.task(0).cost(2).spawn();
        let b = s.task(0).cost(3).spawn();
        s.add_lock(b, r);
        s.add_unlock(a, b);
        s.prepare().unwrap();
        let sink = Arc::new(Collect(Mutex::new(Vec::new())));
        s.set_ready_sink(Some(Arc::clone(&sink) as Arc<dyn ReadySink>));
        s.start().unwrap();
        // The root went to the sink, not the internal queues.
        assert_eq!(s.queues[0].len() + s.queues[1].len(), 0);
        assert_eq!(s.queued_hint(), 1);
        assert_eq!(*sink.0.lock().unwrap(), vec![(a, 5, None)]);
        assert!(s.try_acquire(a));
        assert_eq!(s.queued_hint(), 0, "try_acquire decrements like gettask");
        s.complete(a);
        // The dependent is announced with its lock as the routing hint.
        assert_eq!(sink.0.lock().unwrap()[1], (b, 3, Some(r)));
        assert!(s.try_acquire(b));
        assert!(s.res.get(r).is_locked(), "acquired task holds its locks");
        s.complete(b);
        assert_eq!(s.waiting(), 0);
        assert!(s.res.all_quiescent());
        // reset_run clears the sink: the next run is internally queued.
        s.reset_run().unwrap();
        s.start().unwrap();
        assert_eq!(sink.0.lock().unwrap().len(), 2, "sink detached by reset_run");
        assert_eq!(s.queued_hint(), 1);
        let mut rng = Rng::new(0);
        let (t1, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(t1, a);
        s.complete(t1);
        let (t2, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(t2, b);
        s.complete(t2);
        assert!(s.res.all_quiescent());
    }

    #[test]
    fn lock_aware_priority_changes_key() {
        let mut cfg = SchedConfig::new(1);
        cfg.flags.lock_aware_priority = true;
        let mut s = Scheduler::new(cfg).unwrap();
        let r0 = s.add_resource(None, OWNER_NONE);
        let r1 = s.add_resource(None, OWNER_NONE);
        // heavy: weight 10 but 2 locks; light: weight 9, no locks.
        let heavy = s.task(0).cost(10).spawn();
        let light = s.task(0).cost(9).spawn();
        s.add_lock(heavy, r0);
        s.add_lock(heavy, r1);
        s.prepare().unwrap();
        s.start().unwrap();
        let mut rng = Rng::new(0);
        // key(heavy) = 10 - 10*2 = -10 < key(light) = 9.
        let (first, _) = s.gettask(0, &mut rng).unwrap();
        assert_eq!(first, light);
        s.complete(first);
        let (second, _) = s.gettask(0, &mut rng).unwrap();
        s.complete(second);
    }

    #[test]
    fn frozen_meta_adoption_across_instances() {
        let build = || {
            let mut s = sched(1);
            let r = s.add_resource(None, OWNER_NONE);
            let a = s.task(0).payload(&7i32).cost(2).spawn();
            let b = s.task(1).cost(3).after([a]).spawn();
            s.add_lock(b, r);
            s.prepare().unwrap();
            s
        };
        let a = build();
        let mut b = build();
        assert!(!Arc::ptr_eq(a.frozen_meta().unwrap(), b.frozen_meta().unwrap()));
        let canon = Arc::clone(a.frozen_meta().unwrap());
        assert!(b.adopt_frozen_meta(&canon));
        assert!(Arc::ptr_eq(a.frozen_meta().unwrap(), b.frozen_meta().unwrap()));
        // Run state stays per-instance despite the shared arenas.
        b.start().unwrap();
        let mut rng = Rng::new(0);
        let (t1, _) = b.gettask(0, &mut rng).unwrap();
        b.record_measured(t1, 123);
        b.complete(t1);
        assert_eq!(b.measured_ns(t1), 123);
        assert_eq!(a.measured_ns(t1), 0, "instance A untouched by B's run");
        let (t2, _) = b.gettask(0, &mut rng).unwrap();
        b.complete(t2);
        assert_eq!(b.waiting(), 0);
        // A structurally different graph refuses adoption.
        let mut c = sched(1);
        c.task(0).spawn();
        c.prepare().unwrap();
        assert!(!c.adopt_frozen_meta(&canon));
    }
}
