//! Scheduler configuration: the rust equivalent of the paper's
//! `qsched_init(s, nr_queues, flags)` plus the knobs the validation
//! section exercises (re-owning, pthread/yield modes, steal policy).

/// How idle workers wait for new tasks (paper Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// OpenMP-like: spin until a task shows up (`qsched_flag_none`).
    Spin,
    /// pthread-like with condition variables: relinquish the CPU while no
    /// task is available (`qsched_flag_yield`).
    Yield,
}

/// Work-stealing victim-selection policy. `Random` is the paper's §3.4
/// behaviour; `WeightAware` is the §5 "Work-stealing" future-work item,
/// implemented here as an ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealPolicy {
    /// Probe other queues in a random order (paper default).
    Random,
    /// Probe queues in descending order of total queued weight (§5 ext.).
    WeightAware,
}

/// How the heap key of a ready task is derived. `CriticalPath` is the
/// paper's scheme (§3.1); `Fifo` mimics dependency-only runtimes that
/// execute tasks roughly in creation order (the OmpSs-like baseline);
/// `Cost` ranks by the task's own cost only (ablation: how much of the
/// win comes from *path* weights rather than just "big tasks first").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyPolicy {
    /// weight = cost + max(dependent weights) — the paper.
    CriticalPath,
    /// Earlier-created tasks first (key = -task id).
    Fifo,
    /// Task's own cost as the key.
    Cost,
}

/// Flag set mirroring `qsched_flag_*`.
#[derive(Clone, Copy, Debug)]
pub struct SchedFlags {
    /// Re-own resources to the acquiring queue on steal (§3.4 `s->reown`).
    pub reown: bool,
    /// Idle-wait mode.
    pub mode: ExecMode,
    /// Steal policy (§5 ablation; `Random` reproduces the paper).
    pub steal: StealPolicy,
    /// §5 "Priorities" extension: penalize tasks whose locks conflict with
    /// many queued tasks when picking from a queue. Off reproduces the paper.
    pub lock_aware_priority: bool,
    /// Replace user-estimated task costs with measured execution times on
    /// re-runs (§3.1: "the actual cost of the same task last time it was
    /// executed").
    pub relearn_costs: bool,
    /// Heap-key derivation (paper = `CriticalPath`).
    pub key_policy: KeyPolicy,
    /// Always-on observability counters on the acquisition hot paths
    /// (`gettask` calls/hits/steals, `try_acquire` attempts/failures;
    /// see `Scheduler::obs_counters`). On by default — the cost is a
    /// couple of relaxed increments on padded lines per task, guarded
    /// to <5% of dispatch overhead by `rust/tests/perf_guard.rs`. Off
    /// is the "compiled out" baseline that guard measures against.
    pub obs_counters: bool,
}

impl Default for SchedFlags {
    fn default() -> Self {
        Self {
            reown: true,
            mode: ExecMode::Spin,
            steal: StealPolicy::Random,
            lock_aware_priority: false,
            relearn_costs: false,
            key_policy: KeyPolicy::CriticalPath,
            obs_counters: true,
        }
    }
}

/// Full scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Number of task queues; the paper uses one per computational thread.
    pub nr_queues: usize,
    pub flags: SchedFlags,
    /// Seed for the random steal order (deterministic experiments).
    pub seed: u64,
    /// Capture per-task timeline records (Figs 9/12/13). Small overhead.
    pub record_timeline: bool,
}

impl SchedConfig {
    pub fn new(nr_queues: usize) -> Self {
        Self {
            nr_queues,
            flags: SchedFlags::default(),
            seed: 0x5EED_0F05,
            record_timeline: false,
        }
    }

    pub fn with_flags(mut self, flags: SchedFlags) -> Self {
        self.flags = flags;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_timeline(mut self, on: bool) -> Self {
        self.record_timeline = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = SchedConfig::new(4);
        assert_eq!(c.nr_queues, 4);
        assert!(c.flags.reown);
        assert_eq!(c.flags.mode, ExecMode::Spin);
        assert_eq!(c.flags.steal, StealPolicy::Random);
        assert!(!c.flags.lock_aware_priority);
    }

    #[test]
    fn builder_chains() {
        let c = SchedConfig::new(2).with_seed(9).with_timeline(true);
        assert_eq!(c.seed, 9);
        assert!(c.record_timeline);
    }
}
