//! Task model (paper §3.1).
//!
//! A task carries a user-defined `type` + opaque `data` payload, the list of
//! tasks it *unlocks* (dependencies stored in reverse), the resources it
//! *locks* (conflicts) and *uses* (affinity hints only), a user-estimated
//! `cost` and the derived critical-path `weight`.

use std::sync::atomic::{AtomicI32, AtomicI64, Ordering};

use super::resource::ResId;

/// Handle to a task within one scheduler (dense index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);

impl TaskId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Lifecycle of a task during one run, used by tests and invariant checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Dependencies unresolved; sitting in the scheduler.
    Waiting,
    /// All dependencies resolved; sitting in some queue.
    Queued,
    /// Acquired by a worker, resources locked.
    Running,
    /// Finished; dependents unlocked.
    Done,
}

/// Per-task flags (`task_flag_*` in the paper's appendix).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskFlags {
    /// Virtual tasks group dependencies but have no action: they are not
    /// passed to the execution function.
    pub virtual_task: bool,
}

/// A single task (paper §3.1 `struct task`).
///
/// The atomic fields (`wait`, `measured_ns`) are the only parts mutated
/// during a parallel run; everything else is frozen by
/// [`super::Scheduler::prepare`].
#[derive(Debug)]
pub struct Task {
    /// Application-defined task type, mapped to a kernel by the exec fn.
    pub type_id: u32,
    pub flags: TaskFlags,
    /// Opaque payload bytes, copied in at `addtask` (paper: `void *data`).
    pub data: Vec<u8>,
    /// Tasks that this task unlocks — dependencies stored in reverse.
    pub unlocks: Vec<TaskId>,
    /// Resources that must be exclusively locked before execution.
    /// Sorted by id in `prepare()` to avoid the dining-philosophers
    /// deadlock (§3.3).
    pub locks: Vec<ResId>,
    /// Resources used but not locked — queue-affinity hints only.
    pub uses: Vec<ResId>,
    /// Relative computational cost (user estimate or relearned).
    pub cost: i64,
    /// Cost of the critical path rooted at this task:
    /// `weight = cost + max(weight of unlocked tasks)` (§3.1).
    pub weight: i64,
    /// Number of unresolved dependencies; decremented by `qsched_done`.
    pub wait: AtomicI32,
    /// Measured execution time (ns) of the last run, for cost relearning.
    pub measured_ns: AtomicI64,
}

impl Task {
    pub fn new(type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> Self {
        Self {
            type_id,
            flags,
            data,
            unlocks: Vec::new(),
            locks: Vec::new(),
            uses: Vec::new(),
            cost: cost.max(1),
            weight: 0,
            wait: AtomicI32::new(0),
            measured_ns: AtomicI64::new(0),
        }
    }

    /// Number of unresolved dependencies right now.
    #[inline]
    pub fn wait_count(&self) -> i32 {
        self.wait.load(Ordering::Acquire)
    }

    /// Decrement the wait counter, returning the *new* value. The caller
    /// (scheduler `done`) enqueues the task when this hits zero.
    #[inline]
    pub fn dec_wait(&self) -> i32 {
        self.wait.fetch_sub(1, Ordering::AcqRel) - 1
    }
}

/// Read-only view of a task handed to the user's execution function,
/// mirroring the `fun(t->type, t->data)` call in `qsched_run` (§3.4).
#[derive(Clone, Copy)]
pub struct TaskView<'a> {
    pub tid: TaskId,
    pub type_id: u32,
    pub data: &'a [u8],
    pub cost: i64,
    pub weight: i64,
}

/// Helpers for encoding small POD payloads into a task's `data` bytes, the
/// way the paper's examples pack `int data[3]` / `struct cell *data[2]`.
pub mod payload {
    /// Encode a slice of i32 parameters.
    pub fn from_i32s(xs: &[i32]) -> Vec<u8> {
        let mut v = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    /// Decode a slice of i32 parameters.
    pub fn to_i32s(data: &[u8]) -> Vec<i32> {
        assert!(data.len() % 4 == 0, "payload not a multiple of 4 bytes");
        data.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Encode a slice of u64 parameters (e.g. indices standing in for the
    /// paper's raw pointers).
    pub fn from_u64s(xs: &[u64]) -> Vec<u8> {
        let mut v = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    /// Decode a slice of u64 parameters.
    pub fn to_u64s(data: &[u8]) -> Vec<u64> {
        assert!(data.len() % 8 == 0, "payload not a multiple of 8 bytes");
        data.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_clamped_positive() {
        let t = Task::new(0, TaskFlags::default(), vec![], -5);
        assert_eq!(t.cost, 1);
        let t = Task::new(0, TaskFlags::default(), vec![], 0);
        assert_eq!(t.cost, 1);
    }

    #[test]
    fn wait_counter_roundtrip() {
        let t = Task::new(1, TaskFlags::default(), vec![], 3);
        t.wait.store(2, Ordering::Release);
        assert_eq!(t.dec_wait(), 1);
        assert_eq!(t.dec_wait(), 0);
        assert_eq!(t.wait_count(), 0);
    }

    #[test]
    fn payload_i32_roundtrip() {
        let xs = [3, -1, 1 << 30];
        let enc = payload::from_i32s(&xs);
        assert_eq!(enc.len(), 12);
        assert_eq!(payload::to_i32s(&enc), xs.to_vec());
    }

    #[test]
    fn payload_u64_roundtrip() {
        let xs = [0u64, u64::MAX, 42];
        assert_eq!(payload::to_u64s(&payload::from_u64s(&xs)), xs.to_vec());
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn payload_bad_len_panics() {
        payload::to_i32s(&[1, 2, 3]);
    }

    #[test]
    fn task_id_display() {
        assert_eq!(TaskId(5).to_string(), "t5");
    }
}
