//! Task model (paper §3.1) — the *builder-side* record.
//!
//! A task carries a user-defined `type` + opaque `data` payload, the list of
//! tasks it *unlocks* (dependencies stored in reverse), the resources it
//! *locks* (conflicts) and *uses* (affinity hints only), and a
//! user-estimated `cost`.
//!
//! [`Task`] only exists while a graph is being *built*. At
//! [`super::Scheduler::prepare`] the whole `Vec<Task>` is frozen into a
//! [`super::compiled::CompiledGraph`] — a CSR/SoA layout with one shared
//! adjacency arena, one payload arena, and cache-line-padded per-run
//! atomics — and every runtime consumer (queues, `gettask`, `complete`,
//! the executors) reads spans of that, never these `Vec`s. The derived
//! critical-path `weight` and the per-run counters (`wait`,
//! `measured_ns`, `learned_ns`) live on the compiled graph only.

use super::resource::ResId;

/// Handle to a task within one scheduler (dense index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);

impl TaskId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Lifecycle of a task during one run, used by tests and invariant checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Dependencies unresolved; sitting in the scheduler.
    Waiting,
    /// All dependencies resolved; sitting in some queue.
    Queued,
    /// Acquired by a worker, resources locked.
    Running,
    /// Finished; dependents unlocked.
    Done,
}

/// Per-task flags (`task_flag_*` in the paper's appendix).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TaskFlags {
    /// Virtual tasks group dependencies but have no action: they are not
    /// passed to the execution function.
    pub virtual_task: bool,
}

/// An application task type: anything that names a `u32` type id (the
/// paper's `int type`). Implemented by the application enums
/// (`QrTask`, `NbTask`, `CholTask`) and by the raw integer types, so
/// both `sched.task(QrTask::Geqrf)` and `sched.task(3u32)` work.
///
/// `type_name` feeds the [`super::registry::KernelRegistry`]
/// introspection (kernel names per binding).
pub trait TaskType: Copy {
    fn type_id(self) -> u32;

    fn type_name(self) -> &'static str {
        "task"
    }
}

impl TaskType for u32 {
    fn type_id(self) -> u32 {
        self
    }
}

impl TaskType for i32 {
    fn type_id(self) -> u32 {
        self as u32
    }
}

impl TaskType for usize {
    fn type_id(self) -> u32 {
        self as u32
    }
}

/// A single task under construction (paper §3.1 `struct task`, build
/// phase only — see the module docs; frozen into the CSR layout by
/// `prepare()`).
#[derive(Debug)]
pub struct Task {
    /// Application-defined task type, mapped to a kernel by the exec fn.
    pub type_id: u32,
    pub flags: TaskFlags,
    /// Opaque payload bytes, copied in at `addtask` (paper: `void *data`).
    pub data: Vec<u8>,
    /// Tasks that this task unlocks — dependencies stored in reverse.
    pub unlocks: Vec<TaskId>,
    /// Resources that must be exclusively locked before execution.
    /// Sorted by id (and ancestor-subsumed) while freezing, to avoid
    /// the dining-philosophers deadlock (§3.3).
    pub locks: Vec<ResId>,
    /// Resources used but not locked — queue-affinity hints only.
    pub uses: Vec<ResId>,
    /// Relative computational cost (user estimate or relearned).
    pub cost: i64,
    /// Learned execution time (ns) carried across a thaw: when a frozen
    /// graph with recorded measurements is thawed for further building,
    /// the snapshot lands here and the next freeze seeds the compiled
    /// run state with it, so `relearn_costs` still sees timings after a
    /// run → mutate → re-`prepare()` cycle. 0 = nothing learned.
    pub learned_ns: i64,
}

impl Task {
    pub fn new(type_id: u32, flags: TaskFlags, data: Vec<u8>, cost: i64) -> Self {
        Self {
            type_id,
            flags,
            data,
            unlocks: Vec::new(),
            locks: Vec::new(),
            uses: Vec::new(),
            cost: cost.max(1),
            learned_ns: 0,
        }
    }

    /// Record an exclusive-lock requirement (`qsched_addlock`).
    #[inline]
    pub fn add_lock(&mut self, r: ResId) {
        self.locks.push(r);
    }

    /// Record a use / affinity hint (`qsched_adduse`).
    #[inline]
    pub fn add_use(&mut self, r: ResId) {
        self.uses.push(r);
    }

    /// Record that this task unlocks `t` (`qsched_addunlock`).
    #[inline]
    pub fn add_unlock(&mut self, t: TaskId) {
        self.unlocks.push(t);
    }
}

/// Read-only view of a task handed to the user's execution function,
/// mirroring the `fun(t->type, t->data)` call in `qsched_run` (§3.4).
/// `data` borrows the compiled graph's shared payload arena.
#[derive(Clone, Copy)]
pub struct TaskView<'a> {
    pub tid: TaskId,
    pub type_id: u32,
    pub data: &'a [u8],
    pub cost: i64,
    pub weight: i64,
}

/// Byte-packing helpers for task payloads, the way the paper's examples
/// pack `int data[3]` / `struct cell *data[2]`.
///
/// Deprecated: the typed [`crate::coordinator::payload::Payload`] trait
/// replaces raw byte packing (`.payload(&(i, j, k))` on a task spec,
/// `<(i32, i32, i32)>::decode(view.data)` in a kernel) with the same
/// little-endian wire format. This module remains as the compatibility shim for
/// out-of-tree callers and the paper-fidelity tests.
pub mod payload {
    /// Encode a slice of i32 parameters.
    #[deprecated(since = "0.3.0", note = "use the typed Payload trait: `(a, b, c).encode()`")]
    pub fn from_i32s(xs: &[i32]) -> Vec<u8> {
        let mut v = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    /// Decode a slice of i32 parameters.
    #[deprecated(since = "0.3.0", note = "use the typed Payload trait: `<(i32, i32)>::decode(data)`")]
    pub fn to_i32s(data: &[u8]) -> Vec<i32> {
        assert!(data.len() % 4 == 0, "payload not a multiple of 4 bytes");
        data.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Encode a slice of u64 parameters (e.g. indices standing in for the
    /// paper's raw pointers).
    #[deprecated(since = "0.3.0", note = "use the typed Payload trait: `(a, b).encode()`")]
    pub fn from_u64s(xs: &[u64]) -> Vec<u8> {
        let mut v = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    /// Decode a slice of u64 parameters.
    #[deprecated(since = "0.3.0", note = "use the typed Payload trait: `<(u64, u64)>::decode(data)`")]
    pub fn to_u64s(data: &[u8]) -> Vec<u64> {
        assert!(data.len() % 8 == 0, "payload not a multiple of 8 bytes");
        data.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy byte-packing shim keeps its own tests
mod tests {
    use super::*;

    #[test]
    fn task_type_impls() {
        assert_eq!(7u32.type_id(), 7);
        assert_eq!(7i32.type_id(), 7);
        assert_eq!(7usize.type_id(), 7);
        assert_eq!(3u32.type_name(), "task");
    }

    #[test]
    fn cost_clamped_positive() {
        let t = Task::new(0, TaskFlags::default(), vec![], -5);
        assert_eq!(t.cost, 1);
        let t = Task::new(0, TaskFlags::default(), vec![], 0);
        assert_eq!(t.cost, 1);
    }

    #[test]
    fn build_record_accumulates() {
        let mut t = Task::new(1, TaskFlags::default(), vec![1, 2], 3);
        t.add_lock(ResId(0));
        t.add_use(ResId(1));
        t.add_unlock(TaskId(4));
        assert_eq!(t.locks, vec![ResId(0)]);
        assert_eq!(t.uses, vec![ResId(1)]);
        assert_eq!(t.unlocks, vec![TaskId(4)]);
    }

    #[test]
    fn payload_i32_roundtrip() {
        let xs = [3, -1, 1 << 30];
        let enc = payload::from_i32s(&xs);
        assert_eq!(enc.len(), 12);
        assert_eq!(payload::to_i32s(&enc), xs.to_vec());
    }

    #[test]
    fn payload_u64_roundtrip() {
        let xs = [0u64, u64::MAX, 42];
        assert_eq!(payload::to_u64s(&payload::from_u64s(&xs)), xs.to_vec());
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn payload_bad_len_panics() {
        payload::to_i32s(&[1, 2, 3]);
    }

    #[test]
    fn task_id_display() {
        assert_eq!(TaskId(5).to_string(), "t5");
    }
}
