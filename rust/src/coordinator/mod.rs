//! The QuickSched scheduler: the paper's L3 coordination contribution.
//!
//! See DESIGN.md for the system inventory. Modules follow the paper's
//! object decomposition (§3): [`task`], [`resource`], [`queue`],
//! [`scheduler`]; plus the two executors ([`exec`] real threads,
//! [`sim`] virtual time), weight computation ([`weights`]), graph
//! statistics ([`graph`]) and run metrics ([`metrics`]).
//!
//! Graphs are built through the typed API — [`GraphBuilder::task`]
//! returning a fluent [`TaskSpec`] with [`Payload`]-typed task data —
//! and executed through a [`KernelRegistry`] binding task types to
//! kernels once per application ([`Scheduler::run_registry`] /
//! [`Scheduler::run_sim_registry`]). The untyped
//! `add_task(type_id, flags, &[u8], cost)` call and the
//! [`task::payload`] byte-packing helpers remain as deprecated shims.
pub mod builder;
pub mod config;
pub mod error;
pub mod exec;
pub mod graph;
pub mod metrics;
pub mod payload;
pub mod queue;
pub mod registry;
pub mod resource;
pub mod scheduler;
pub mod sim;
pub mod spec;
pub mod task;
pub mod weights;

pub use builder::GraphBuilder;
pub use config::{ExecMode, KeyPolicy, SchedConfig, SchedFlags, StealPolicy};
pub use error::{Result, SchedError};
pub use graph::GraphStats;
pub use metrics::{RunMetrics, TimelineRecord};
pub use payload::Payload;
pub use registry::KernelRegistry;
pub use resource::{ResId, Resource, OWNER_NONE};
pub use scheduler::{ResHandle, Scheduler, TaskHandle};
pub use sim::{ContentionCost, CostModel, ScaledCost, SimCtx, UnitCost};
pub use spec::TaskSpec;
pub use task::{Task, TaskFlags, TaskId, TaskState, TaskType, TaskView};
