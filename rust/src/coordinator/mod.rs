//! The QuickSched scheduler: the paper's L3 coordination contribution.
//!
//! See DESIGN.md for the system inventory. Modules follow the paper's
//! object decomposition (§3): [`task`], [`resource`], [`queue`],
//! [`scheduler`]; plus the two executors ([`exec`] real threads,
//! [`sim`] virtual time), weight computation ([`weights`]), graph
//! statistics ([`graph`]) and run metrics ([`metrics`]).
pub mod builder;
pub mod config;
pub mod error;
pub mod exec;
pub mod graph;
pub mod metrics;
pub mod queue;
pub mod resource;
pub mod scheduler;
pub mod sim;
pub mod task;
pub mod weights;

pub use builder::GraphBuilder;
pub use config::{ExecMode, KeyPolicy, SchedConfig, SchedFlags, StealPolicy};
pub use error::{Result, SchedError};
pub use graph::GraphStats;
pub use metrics::{RunMetrics, TimelineRecord};
pub use resource::{ResId, Resource, OWNER_NONE};
pub use scheduler::{ResHandle, Scheduler, TaskHandle};
pub use sim::{ContentionCost, CostModel, ScaledCost, SimCtx, UnitCost};
pub use task::{payload, Task, TaskFlags, TaskId, TaskState, TaskView};
