//! The QuickSched scheduler: the paper's L3 coordination contribution.
//!
//! See DESIGN.md for the system inventory. Modules follow the paper's
//! object decomposition (§3): [`task`], [`resource`], [`queue`],
//! [`scheduler`]; plus the two executors ([`exec`] real threads,
//! [`sim`] virtual time), weight computation ([`weights`]), graph
//! statistics ([`graph`]) and run metrics ([`metrics`]).
//!
//! Graphs are built through the typed API — [`GraphBuilder::task`]
//! returning a fluent [`TaskSpec`] with [`Payload`]-typed task data —
//! and executed through a [`KernelRegistry`] binding task types to
//! kernels once per application ([`Scheduler::run_registry`] /
//! [`Scheduler::run_sim_registry`]). The untyped
//! `add_task(type_id, flags, &[u8], cost)` call and the
//! [`task::payload`] byte-packing helpers remain as deprecated shims.
//!
//! # Lifecycle of a task
//!
//! 1. **Build** — `sched.task(ty).payload(&…).cost(c).locks([r]).spawn()`
//!    records the task; `prepare()` validates the graph and *freezes*
//!    it into the CSR/SoA [`CompiledGraph`] ([`compiled`]): one shared
//!    `u32` adjacency arena, one payload arena, sorted lock sets,
//!    precomputed wait counts, critical-path weights, and a
//!    cache-line-padded per-run state array. Every runtime path below
//!    reads spans of that layout (see ARCHITECTURE.md §Memory layout).
//! 2. **Ready** — `start()` (or a dependency resolution inside
//!    [`Scheduler::complete`]) announces the task: either into one of
//!    the scheduler's own per-worker [`queue::Queue`]s (routed by
//!    resource-owner affinity, paper §3.4), or — when a [`ReadySink`]
//!    is installed — into the server's shared cross-job shard layer
//!    (`server::shard`), tagged with its job.
//! 3. **Acquired** — a worker claims it via [`Scheduler::gettask`]
//!    (internal queues + random-order stealing) or
//!    [`Scheduler::try_acquire`] (shard path); either way the task's
//!    resources are exclusively locked.
//! 4. **Complete** — [`Scheduler::complete`] unlocks resources,
//!    decrements dependents' wait counters, and announces newly-ready
//!    dependents, returning to step 2 until `waiting()` hits zero.
//!
//! See `ARCHITECTURE.md` at the repo root for the cross-module data-flow
//! picture of the server's sharded dispatch built on these hooks.
pub mod builder;
pub mod compiled;
pub mod config;
pub mod error;
pub mod exec;
pub mod graph;
pub mod metrics;
pub mod payload;
pub mod queue;
pub mod registry;
pub mod resource;
pub mod scheduler;
pub mod sim;
pub mod spec;
pub mod task;
pub mod weights;

pub use builder::GraphBuilder;
pub use compiled::{CompiledGraph, FrozenGraph, Span, TaskRunState};
pub use config::{ExecMode, KeyPolicy, SchedConfig, SchedFlags, StealPolicy};
pub use error::{Result, SchedError};
pub use graph::GraphStats;
pub use metrics::{RunMetrics, TimelineRecord};
pub use payload::Payload;
pub use registry::KernelRegistry;
pub use resource::{ResId, Resource, OWNER_NONE};
pub use queue::{Take, TaggedQueue};
pub use scheduler::{ReadySink, ResHandle, Scheduler, TaskHandle};
pub use sim::{ContentionCost, CostModel, ScaledCost, SimCtx, UnitCost};
pub use spec::TaskSpec;
pub use task::{Task, TaskFlags, TaskId, TaskState, TaskType, TaskView};
