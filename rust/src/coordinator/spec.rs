//! The fluent task builder: [`TaskSpec`].
//!
//! `TaskSpec` replaces the paper-style four-call build sequence
//! (`qsched_addtask` + N × `addlock`/`adduse`/`addunlock`) with one
//! validated, typed expression:
//!
//! ```
//! use quicksched::coordinator::{GraphBuilder, SchedConfig, Scheduler};
//!
//! let mut sched = Scheduler::new(SchedConfig::new(2)).unwrap();
//! let tile = sched.add_resource(None, 0);
//! let a = sched.task(0u32).payload(&(0i32, 0i32)).cost(4).lock(tile).spawn();
//! let b = sched.task(1u32).payload(&(0i32, 1i32)).cost(2).after([a]).spawn();
//! sched.prepare().unwrap();
//! assert_eq!(sched.stats().tasks, 2);
//! assert_eq!(sched.stats().dependencies, 1);
//! # let _ = b;
//! ```
//!
//! The spec validates at [`TaskSpec::spawn`] time — unknown resource or
//! task handles, duplicate locks, and locks on virtual tasks (which
//! never execute, so their locks would be silently ignored) are build
//! errors instead of latent graph bugs. `spawn()` panics on a bad spec;
//! [`TaskSpec::try_spawn`] returns the error for callers that prefer to
//! handle it.
//!
//! Dependencies (`after`) accept any `IntoIterator<Item = TaskHandle>`,
//! so an `Option<TaskHandle>` ("the previous task at this tile, if any")
//! works directly — a pattern both application graph generators use.

use super::builder::GraphBuilder;
use super::error::{Result, SchedError};
use super::payload::Payload;
use super::scheduler::{ResHandle, TaskHandle};
use super::task::TaskFlags;

/// One task under construction against a [`GraphBuilder`]. Created by
/// [`GraphBuilder::task`]; consumed by [`TaskSpec::spawn`] /
/// [`TaskSpec::try_spawn`].
#[must_use = "a TaskSpec does nothing until .spawn() is called"]
pub struct TaskSpec<'b, B: GraphBuilder + ?Sized> {
    builder: &'b mut B,
    type_id: u32,
    flags: TaskFlags,
    data: Vec<u8>,
    cost: i64,
    locks: Vec<ResHandle>,
    uses: Vec<ResHandle>,
    after: Vec<TaskHandle>,
}

impl<'b, B: GraphBuilder + ?Sized> TaskSpec<'b, B> {
    pub(crate) fn new(builder: &'b mut B, type_id: u32) -> Self {
        Self {
            builder,
            type_id,
            flags: TaskFlags::default(),
            data: Vec::new(),
            cost: 1,
            locks: Vec::new(),
            uses: Vec::new(),
            after: Vec::new(),
        }
    }

    /// Typed payload (replaces raw byte packing; see [`Payload`]).
    pub fn payload<P: Payload>(mut self, p: &P) -> Self {
        self.data = p.encode();
        self
    }

    /// User-estimated relative cost (§3.1); defaults to 1, clamped ≥ 1.
    pub fn cost(mut self, cost: i64) -> Self {
        self.cost = cost;
        self
    }

    /// Mark as a virtual task: groups dependencies, has no action and is
    /// never handed to a kernel (`task_flag_virtual`).
    pub fn virtual_task(mut self) -> Self {
        self.flags.virtual_task = true;
        self
    }

    /// Exclusively lock `r` for the task's execution (`qsched_addlock`).
    pub fn lock(mut self, r: ResHandle) -> Self {
        self.locks.push(r);
        self
    }

    /// Lock every resource in `rs`.
    pub fn locks<I: IntoIterator<Item = ResHandle>>(mut self, rs: I) -> Self {
        self.locks.extend(rs);
        self
    }

    /// Use `r` without locking — a queue-affinity hint (`qsched_adduse`).
    pub fn use_res(mut self, r: ResHandle) -> Self {
        self.uses.push(r);
        self
    }

    /// Use every resource in `rs` (affinity hints).
    pub fn uses<I: IntoIterator<Item = ResHandle>>(mut self, rs: I) -> Self {
        self.uses.extend(rs);
        self
    }

    /// Run only after every task in `ts` (`qsched_addunlock` edges).
    /// Accepts arrays, iterators, or an `Option<TaskHandle>`.
    pub fn after<I: IntoIterator<Item = TaskHandle>>(mut self, ts: I) -> Self {
        self.after.extend(ts);
        self
    }

    /// Validate and emit the task into the builder, returning its handle.
    ///
    /// Validation: every `lock`/`use` names an existing resource, every
    /// `after` names an existing task, no resource is locked twice, and
    /// virtual tasks lock nothing.
    pub fn try_spawn(self) -> Result<TaskHandle> {
        let nt = self.builder.nr_tasks_built();
        let nr = self.builder.nr_resources_built();
        for &r in self.locks.iter().chain(self.uses.iter()) {
            if r.idx() >= nr {
                return Err(SchedError::BadRes(r.0, nr));
            }
        }
        for (i, &a) in self.locks.iter().enumerate() {
            if self.locks[..i].contains(&a) {
                return Err(SchedError::DuplicateLock(a.0));
            }
        }
        for &t in &self.after {
            if t.idx() >= nt {
                return Err(SchedError::BadTask(t.0, nt));
            }
        }
        if self.flags.virtual_task && !self.locks.is_empty() {
            return Err(SchedError::VirtualTaskLocks(self.locks.len()));
        }
        let t = self
            .builder
            .raw_task(self.type_id, self.flags, self.data, self.cost);
        for &dep in &self.after {
            self.builder.add_unlock(dep, t);
        }
        for &r in &self.locks {
            self.builder.add_lock(t, r);
        }
        for &r in &self.uses {
            self.builder.add_use(t, r);
        }
        Ok(t)
    }

    /// [`TaskSpec::try_spawn`], panicking on an invalid spec. Graph
    /// construction is single-threaded setup code, where a malformed
    /// spec is a programming error.
    pub fn spawn(self) -> TaskHandle {
        let type_id = self.type_id;
        self.try_spawn()
            .unwrap_or_else(|e| panic!("invalid task spec (type {type_id}): {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ResId, SchedConfig, Scheduler, TaskId};

    fn sched() -> Scheduler {
        Scheduler::new(SchedConfig::new(2)).unwrap()
    }

    #[test]
    fn fluent_build_matches_raw_build() {
        let mut s = sched();
        let r0 = s.add_resource(None, 0);
        let r1 = s.add_resource(None, 1);
        let a = s.task(0u32).payload(&(1i32, 2i32, 3i32)).cost(10).lock(r0).spawn();
        let b = s
            .task(1u32)
            .cost(5)
            .locks([r1])
            .use_res(r0)
            .after([a])
            .spawn();
        s.prepare().unwrap();
        let st = s.stats();
        assert_eq!((st.tasks, st.locks, st.uses, st.dependencies), (2, 2, 1, 1));
        assert_eq!(st.payload_bytes, 12);
        let va = s.task_view(a);
        assert_eq!(va.cost, 10);
        assert_eq!(va.weight, 15, "a unlocks b: weight = 10 + 5");
        let _ = b;
    }

    #[test]
    fn after_accepts_option() {
        let mut s = sched();
        let mut prev: Option<TaskHandle> = None;
        for i in 0..4 {
            prev = Some(s.task(0u32).cost(1 + i).after(prev).spawn());
        }
        s.prepare().unwrap();
        assert_eq!(s.stats().dependencies, 3);
        assert_eq!(s.stats().roots, 1);
    }

    #[test]
    fn unknown_resource_rejected() {
        let mut s = sched();
        let err = s.task(0u32).lock(ResId(7)).try_spawn().unwrap_err();
        assert!(matches!(err, SchedError::BadRes(7, 0)));
        assert_eq!(s.nr_tasks(), 0, "nothing emitted on a failed spec");
    }

    #[test]
    fn unknown_dependency_rejected() {
        let mut s = sched();
        let err = s.task(0u32).after([TaskId(3)]).try_spawn().unwrap_err();
        assert!(matches!(err, SchedError::BadTask(3, 0)));
    }

    #[test]
    fn duplicate_lock_rejected() {
        let mut s = sched();
        let r = s.add_resource(None, 0);
        let err = s.task(0u32).lock(r).lock(r).try_spawn().unwrap_err();
        assert!(matches!(err, SchedError::DuplicateLock(0)));
    }

    #[test]
    fn virtual_task_with_locks_rejected() {
        let mut s = sched();
        let r = s.add_resource(None, 0);
        let err = s.task(0u32).virtual_task().lock(r).try_spawn().unwrap_err();
        assert!(matches!(err, SchedError::VirtualTaskLocks(1)));
    }

    #[test]
    #[should_panic(expected = "invalid task spec")]
    fn spawn_panics_on_bad_spec() {
        let mut s = sched();
        s.task(0u32).lock(ResId(1)).spawn();
    }

    #[test]
    fn virtual_task_flag_propagates() {
        let mut s = sched();
        let v = s.task(0u32).virtual_task().spawn();
        let b = s.task(0u32).after([v]).spawn();
        s.prepare().unwrap();
        s.start().unwrap();
        // The virtual root completes in place; only b remains.
        assert_eq!(s.waiting(), 1);
        let _ = b;
    }
}
